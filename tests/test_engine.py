"""Engine-layer tests: backend dispatch, compaction policies, sharding,
and sparse-vs-dense read-path equivalence.

These use deterministic randomized schedules (seeded numpy) rather than
hypothesis, so they run everywhere — including environments where the
optional test deps are absent. The hypothesis interleaving property for
the single tree lives in test_slsm_props.py.
"""
import numpy as np
import pytest

from repro.core import SLSM, SLSMParams
from repro.core.oracle import DictOracle
from repro.engine import (LevelingPolicy, ShardedSLSM, TieringPolicy,
                          get_backend, shard_ids)

SMALL = SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=512, cand_factor=16)
KEY_SPACE = 200


def _random_schedule(t, o, seed, rounds=8, key_space=KEY_SPACE):
    """Randomized insert/delete stream driving seals, flushes, and
    cascaded merges on the tiny geometry (and the same ops on the
    oracle)."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        if rng.random() < 0.75:
            n = int(rng.integers(1, 40))
            ks = rng.integers(0, key_space, n).astype(np.int32)
            vs = rng.integers(-50, 50, n).astype(np.int32)
            t.insert(ks, vs)
            o.insert(ks, vs)
        else:
            n = int(rng.integers(1, 12))
            ks = rng.integers(0, key_space, n).astype(np.int32)
            t.delete(ks)
            o.delete(ks)
    return np.arange(-4, key_space + 4, dtype=np.int32)


# -- sparse vs dense read-path equivalence ----------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_sparse_matches_dense_and_oracle(seed):
    """With sufficient cand_factor headroom the Bloom-compacted (sparse)
    disk search must agree with the dense path and the dict oracle across
    randomized insert/delete/merge schedules (total resident runs here is
    <= D * max_levels = 6 < cand_factor = 16, so the gate never
    overflows)."""
    t, o = SLSM(SMALL), DictOracle()
    qs = _random_schedule(t, o, seed)
    assert t.n_levels >= 1  # merges actually happened
    vd, fd = t.lookup(qs, sparse=False)
    vs_, fs = t.lookup(qs, sparse=True)
    vo, fo = o.lookup(qs)
    np.testing.assert_array_equal(fd, fo)
    np.testing.assert_array_equal(vd[fd], vo[fo])
    np.testing.assert_array_equal(fs, fo)
    np.testing.assert_array_equal(vs_[fs], vo[fo])


# -- backend dispatch --------------------------------------------------------

def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        SLSMParams(backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        get_backend("cuda")


@pytest.mark.parametrize("seed", range(2))
def test_pallas_backend_matches_jnp(seed):
    """backend="pallas" routes Bloom probes, fence lookups, and merges
    through the kernels (interpret mode off-TPU) and must be observationally
    identical to the jnp reference."""
    pj = SMALL
    pp = SLSMParams(**{**pj.__dict__, "backend": "pallas"})
    tj, tp, o = SLSM(pj), SLSM(pp), DictOracle()
    rng = np.random.default_rng(seed)
    for _ in range(5):
        n = int(rng.integers(1, 32))
        ks = rng.integers(0, KEY_SPACE, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        tj.insert(ks, vs)
        tp.insert(ks, vs)
        o.insert(ks, vs)
    dels = rng.integers(0, KEY_SPACE, 8).astype(np.int32)
    tj.delete(dels), tp.delete(dels), o.delete(dels)
    assert tp.n_levels >= 1  # kernel merge path exercised

    qs = np.arange(-4, KEY_SPACE + 4, dtype=np.int32)
    vj, fj = tj.lookup(qs)
    vp, fp = tp.lookup(qs)
    vo, fo = o.lookup(qs)
    np.testing.assert_array_equal(fj, fo)
    np.testing.assert_array_equal(fp, fo)
    np.testing.assert_array_equal(vj[fj], vo[fo])
    np.testing.assert_array_equal(vp[fp], vo[fo])

    kj, wj = tj.range(5, 150)
    kp, wp = tp.range(5, 150)
    np.testing.assert_array_equal(kj, kp)
    np.testing.assert_array_equal(wj, wp)


# -- compaction policies -----------------------------------------------------

def test_leveling_policy_matches_oracle_and_bounds_runs():
    p = SLSMParams(R=2, Rn=8, eps=0.05, D=2, m=1.0, mu=4, max_levels=4,
                   max_range=512)
    t, o = SLSM(p, policy=LevelingPolicy()), DictOracle()
    qs = _random_schedule(t, o, seed=3, rounds=10)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    k1, w1 = t.range(10, 180)
    k2, w2 = o.range(10, 180)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(w1, w2)
    # the policy's read-amplification promise: <= max_resident runs/level
    for lv in t.state.levels:
        assert int(lv.n_runs) <= 2


def test_leveling_policy_rejects_unsupported_geometry():
    # ceil(m*D) = 1 < max_resident: a spill could not fit the next level
    with pytest.raises(ValueError, match="LevelingPolicy"):
        SLSM(SLSMParams(R=3, Rn=8, D=2, m=0.5, mu=4), policy=LevelingPolicy())


def test_tiering_policy_is_default_paper_behaviour():
    t = SLSM(SMALL)
    assert isinstance(t.policy, TieringPolicy)
    assert t.policy.runs_to_spill(SMALL, SMALL.D) == SMALL.disk_runs_merged


# -- sharded engine ----------------------------------------------------------

def test_shard_routing_is_deterministic_and_covers_shards():
    keys = np.arange(4096, dtype=np.int32)
    sid = shard_ids(keys, 4)
    np.testing.assert_array_equal(sid, shard_ids(keys, 4))
    assert set(np.unique(sid)) == {0, 1, 2, 3}
    # hash routing should be roughly balanced on sequential keys
    counts = np.bincount(sid, minlength=4)
    assert counts.min() > len(keys) // 8


@pytest.mark.parametrize("seed", range(3))
def test_sharded_matches_oracle(seed):
    t, o = ShardedSLSM(SMALL, n_shards=4), DictOracle()
    rng = np.random.default_rng(seed)
    for _ in range(6):
        n = int(rng.integers(1, 120))
        ks = rng.integers(0, 500, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, 500, int(rng.integers(1, 16))).astype(np.int32)
        t.delete(dels)
        o.delete(dels)
    qs = np.arange(-4, 504, dtype=np.int32)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    k1, w1 = t.range(20, 480)
    k2, w2 = o.range(20, 480)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(w1, w2)


def test_sharded_cascade_reaches_disk_levels():
    """Enough volume to force every shard through flushes and level spills."""
    t, o = ShardedSLSM(SMALL, n_shards=4), DictOracle()
    rng = np.random.default_rng(7)
    # 600 keys over a 800-key space: every shard (~150 keys) overflows its
    # memory buffer (R*Rn = 16) several times over, without exceeding the
    # tiny geometry's declared total capacity
    ks = rng.integers(0, 800, 600).astype(np.int32)
    vs = rng.integers(0, 100, 600).astype(np.int32)
    t.insert(ks, vs)
    o.insert(ks, vs)
    occ = t.shard_occupancy()
    assert (occ > 0).all()
    disk = sum(int(lv.counts.sum()) for lv in t.state.levels)
    assert disk > 0  # flush/cascade actually ran
    qs = rng.integers(-10, 810, 512).astype(np.int32)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])


# -- range-query correctness under updates/deletes ---------------------------

def test_range_survives_overwrites_and_deletes():
    """Regression (ISSUE 3): per-structure range windows used to be cut to
    max_range BEFORE newest-wins dedup, so stale versions and tombstones
    occupying window slots silently evicted live keys even when the final
    count was far below max_range. Overwrite/delete a key range, then
    scan it: the survivors must all be visible."""
    p = SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=16)
    t, o = SLSM(p), DictOracle()
    keys = np.arange(0, 40, dtype=np.int32)
    t.insert(keys, keys)
    o.insert(keys, keys)
    # push the originals toward disk, then tombstone most of the range:
    # the deep run's first max_range slots are now all-stale
    t.delete(keys[:32])
    o.delete(keys[:32])
    k1, v1 = t.range(0, 80)
    k2, v2 = o.range(0, 80)
    assert len(k2) == 8 < p.max_range   # survivors fit well under the cap
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)
    # same data, new values: overwrites must win without evicting anyone
    t.insert(keys[32:], keys[32:] * 10)
    o.insert(keys[32:], keys[32:] * 10)
    k1, v1, trunc = t.range(0, 80, return_truncated=True)
    k2, v2 = o.range(0, 80)
    assert not trunc
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)


def test_range_truncation_flag_single_tree():
    p = SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=16)
    t = SLSM(p)
    keys = np.arange(0, 64, dtype=np.int32)
    t.insert(keys, keys)
    k, v, trunc = t.range(0, 64, return_truncated=True)
    assert trunc and len(k) == p.max_range
    np.testing.assert_array_equal(k, keys[:p.max_range])
    k, v, trunc = t.range(0, 10, return_truncated=True)
    assert not trunc and len(k) == 10


def test_sharded_range_parity_and_truncated_flags():
    """ShardedSLSM.range vs the single tree over hash-skewed keys: exact
    (and flag-free) while no shard truncates; per-shard flags light up
    exactly for the shards that hold more than max_range live keys."""
    p = SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=64)
    n_shards = 4
    # hash-skew: only keys routed to shards 0 and 1 (40 each, under the
    # per-shard max_range), so the other shards stay empty — the
    # imbalance the parity claim must survive without truncating
    pool = np.arange(0, 4000, dtype=np.int32)
    sid = shard_ids(pool, n_shards)
    skewed = np.concatenate([pool[sid == 0][:40], pool[sid == 1][:40]])
    s = ShardedSLSM(p, n_shards=n_shards)
    t = SLSM(SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                        max_range=4096))   # wide enough to never truncate
    vals = (skewed * 3).astype(np.int32)
    s.insert(skewed, vals)
    t.insert(skewed, vals)
    lo, hi = int(pool[0]), int(pool[-1]) + 1
    ks, vs, trunc = s.range(lo, hi, return_truncated=True)
    kt, vt = t.range(lo, hi)
    assert trunc.shape == (n_shards,)
    assert not trunc.any()
    np.testing.assert_array_equal(ks, kt)
    np.testing.assert_array_equal(vs, vt)
    # force a truncating shard: more than max_range live keys on shard 0
    hot = pool[shard_ids(pool, n_shards) == 0][:p.max_range + 8]
    s2 = ShardedSLSM(p, n_shards=n_shards)
    s2.insert(hot, hot)
    _, _, trunc2 = s2.range(lo, hi, return_truncated=True)
    assert bool(trunc2[0])
    assert not trunc2[1:].any()


# -- reserved-sentinel rejection at the API boundary -------------------------

@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_reserved_sentinels_rejected(engine):
    from repro.core.params import KEY_EMPTY
    t = (SLSM(SMALL) if engine == "single"
         else ShardedSLSM(SMALL, n_shards=2))
    ok_keys = np.asarray([1, 2], np.int32)
    with pytest.raises(ValueError, match="KEY_EMPTY"):
        t.insert(np.asarray([1, KEY_EMPTY], np.int32), ok_keys)
    with pytest.raises(ValueError, match="KEY_EMPTY"):
        t.delete(np.asarray([KEY_EMPTY], np.int32))
    with pytest.raises(ValueError, match="KEY_EMPTY"):
        t.lookup(np.asarray([KEY_EMPTY], np.int32))
    with pytest.raises(ValueError, match="KEY_EMPTY"):
        t.lookup_many(np.asarray([3, KEY_EMPTY], np.int32))
    # the regression the guard closes: a KEY_EMPTY lookup used to
    # false-positive against empty stage slots (seq 0 >= 0); and the
    # extreme-but-legal neighbour key must still work
    t.insert(np.asarray([KEY_EMPTY - 1], np.int32),
             np.asarray([77], np.int32))
    vals, found = t.lookup(np.asarray([KEY_EMPTY - 1], np.int32))
    assert found.all() and vals[0] == 77


@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_full_int32_value_domain_round_trips(engine):
    """Regression (ISSUE 8): the legacy engine reserved TOMBSTONE
    (int32 min) as a value sentinel and rejected it at insert. The
    weighted record algebra carries deletes in the weight lane, so
    EVERY int32 is now a legal value — including the old sentinel and
    both domain extremes — and must round-trip through insert, lookup,
    delete, and re-insert."""
    t = (SLSM(SMALL) if engine == "single"
         else ShardedSLSM(SMALL, n_shards=2))
    lo, hi = np.iinfo(np.int32).min, np.iinfo(np.int32).max
    keys = np.asarray([10, 20, 30, 40], np.int32)
    vals = np.asarray([lo, lo + 1, hi, 0], np.int32)  # lo == old TOMBSTONE
    t.insert(keys, vals)
    got, found = t.lookup_many(keys)
    assert found.all()
    np.testing.assert_array_equal(np.asarray(got), vals)
    # extreme values survive delete + re-insert (newest-wins)
    t.delete(keys[:2])
    _, found = t.lookup_many(keys[:2])
    assert not np.asarray(found).any()
    t.insert(keys[:2], vals[2:])
    got, found = t.lookup_many(keys)
    assert np.asarray(found).all()
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray([hi, 0, hi, 0], np.int32))
    # range scans return the sentinel-valued rows too
    rk, rv = t.range(5, 45)
    np.testing.assert_array_equal(np.asarray(rk), keys)
    np.testing.assert_array_equal(np.asarray(rv),
                                  np.asarray([hi, 0, hi, 0], np.int32))


# -- seqno uniqueness across chunked inserts ---------------------------------

def _live_seqnos(state):
    out = [np.asarray(state.stage_seqs)[:int(state.stage_count)]]
    counts = np.asarray(state.buf_counts)
    for r in range(int(state.run_count)):
        out.append(np.asarray(state.buf_seqs)[r, :counts[r]])
    for lv in state.levels:
        lc = np.asarray(lv.counts)
        for d in range(int(lv.n_runs)):
            out.append(np.asarray(lv.seqs)[d, :lc[d]])
    return np.concatenate(out) if out else np.zeros(0, np.int64)


@pytest.mark.parametrize("seed", range(3))
def test_global_seqno_uniqueness_across_chunked_inserts(seed):
    """Regression (ISSUE 3): stage_append used to stamp seqnos on padded
    lanes while advancing next_seq only by n_valid, so pad-lane seqnos
    overlapped the next chunk's live range. Drive odd-sized (sub-Rn)
    chunks — every surviving seqno must be unique and < next_seq."""
    t = SLSM(SMALL)
    rng = np.random.default_rng(seed)
    total = 0
    for _ in range(12):
        n = int(rng.integers(1, SMALL.Rn))       # always a padded chunk
        ks = rng.integers(0, 500, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        total += n
        seqs = _live_seqnos(t.state)
        assert len(np.unique(seqs)) == len(seqs)
        assert int(t.state.next_seq) == total
        assert seqs.size == 0 or seqs.max() < total


# -- back-compat facade ------------------------------------------------------

def test_core_slsm_facade_exports():
    from repro.core import slsm
    for name in ("SLSM", "SLSMState", "LevelState", "init_state",
                 "lookup_batch", "range_query", "merge_buffer_to_level0",
                 "merge_level_down", "compact_last_level", "ShardedSLSM"):
        assert hasattr(slsm, name), name
    from repro.core import SLSM as core_slsm
    from repro.engine import SLSM as engine_slsm
    assert core_slsm is engine_slsm
