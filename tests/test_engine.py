"""Engine-layer tests: backend dispatch, compaction policies, sharding,
and sparse-vs-dense read-path equivalence.

These use deterministic randomized schedules (seeded numpy) rather than
hypothesis, so they run everywhere — including environments where the
optional test deps are absent. The hypothesis interleaving property for
the single tree lives in test_slsm_props.py.
"""
import numpy as np
import pytest

from repro.core import SLSM, SLSMParams
from repro.core.oracle import DictOracle
from repro.engine import (LevelingPolicy, ShardedSLSM, TieringPolicy,
                          get_backend, shard_ids)

SMALL = SLSMParams(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=512, cand_factor=16)
KEY_SPACE = 200


def _random_schedule(t, o, seed, rounds=8, key_space=KEY_SPACE):
    """Randomized insert/delete stream driving seals, flushes, and
    cascaded merges on the tiny geometry (and the same ops on the
    oracle)."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        if rng.random() < 0.75:
            n = int(rng.integers(1, 40))
            ks = rng.integers(0, key_space, n).astype(np.int32)
            vs = rng.integers(-50, 50, n).astype(np.int32)
            t.insert(ks, vs)
            o.insert(ks, vs)
        else:
            n = int(rng.integers(1, 12))
            ks = rng.integers(0, key_space, n).astype(np.int32)
            t.delete(ks)
            o.delete(ks)
    return np.arange(-4, key_space + 4, dtype=np.int32)


# -- sparse vs dense read-path equivalence ----------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_sparse_matches_dense_and_oracle(seed):
    """With sufficient cand_factor headroom the Bloom-compacted (sparse)
    disk search must agree with the dense path and the dict oracle across
    randomized insert/delete/merge schedules (total resident runs here is
    <= D * max_levels = 6 < cand_factor = 16, so the gate never
    overflows)."""
    t, o = SLSM(SMALL), DictOracle()
    qs = _random_schedule(t, o, seed)
    assert t.n_levels >= 1  # merges actually happened
    vd, fd = t.lookup(qs, sparse=False)
    vs_, fs = t.lookup(qs, sparse=True)
    vo, fo = o.lookup(qs)
    np.testing.assert_array_equal(fd, fo)
    np.testing.assert_array_equal(vd[fd], vo[fo])
    np.testing.assert_array_equal(fs, fo)
    np.testing.assert_array_equal(vs_[fs], vo[fo])


# -- backend dispatch --------------------------------------------------------

def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        SLSMParams(backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        get_backend("cuda")


@pytest.mark.parametrize("seed", range(2))
def test_pallas_backend_matches_jnp(seed):
    """backend="pallas" routes Bloom probes, fence lookups, and merges
    through the kernels (interpret mode off-TPU) and must be observationally
    identical to the jnp reference."""
    pj = SMALL
    pp = SLSMParams(**{**pj.__dict__, "backend": "pallas"})
    tj, tp, o = SLSM(pj), SLSM(pp), DictOracle()
    rng = np.random.default_rng(seed)
    for _ in range(5):
        n = int(rng.integers(1, 32))
        ks = rng.integers(0, KEY_SPACE, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        tj.insert(ks, vs)
        tp.insert(ks, vs)
        o.insert(ks, vs)
    dels = rng.integers(0, KEY_SPACE, 8).astype(np.int32)
    tj.delete(dels), tp.delete(dels), o.delete(dels)
    assert tp.n_levels >= 1  # kernel merge path exercised

    qs = np.arange(-4, KEY_SPACE + 4, dtype=np.int32)
    vj, fj = tj.lookup(qs)
    vp, fp = tp.lookup(qs)
    vo, fo = o.lookup(qs)
    np.testing.assert_array_equal(fj, fo)
    np.testing.assert_array_equal(fp, fo)
    np.testing.assert_array_equal(vj[fj], vo[fo])
    np.testing.assert_array_equal(vp[fp], vo[fo])

    kj, wj = tj.range(5, 150)
    kp, wp = tp.range(5, 150)
    np.testing.assert_array_equal(kj, kp)
    np.testing.assert_array_equal(wj, wp)


# -- compaction policies -----------------------------------------------------

def test_leveling_policy_matches_oracle_and_bounds_runs():
    p = SLSMParams(R=2, Rn=8, eps=0.05, D=2, m=1.0, mu=4, max_levels=4,
                   max_range=512)
    t, o = SLSM(p, policy=LevelingPolicy()), DictOracle()
    qs = _random_schedule(t, o, seed=3, rounds=10)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    k1, w1 = t.range(10, 180)
    k2, w2 = o.range(10, 180)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(w1, w2)
    # the policy's read-amplification promise: <= max_resident runs/level
    for lv in t.state.levels:
        assert int(lv.n_runs) <= 2


def test_leveling_policy_rejects_unsupported_geometry():
    # ceil(m*D) = 1 < max_resident: a spill could not fit the next level
    with pytest.raises(ValueError, match="LevelingPolicy"):
        SLSM(SLSMParams(R=3, Rn=8, D=2, m=0.5, mu=4), policy=LevelingPolicy())


def test_tiering_policy_is_default_paper_behaviour():
    t = SLSM(SMALL)
    assert isinstance(t.policy, TieringPolicy)
    assert t.policy.runs_to_spill(SMALL, SMALL.D) == SMALL.disk_runs_merged


# -- sharded engine ----------------------------------------------------------

def test_shard_routing_is_deterministic_and_covers_shards():
    keys = np.arange(4096, dtype=np.int32)
    sid = shard_ids(keys, 4)
    np.testing.assert_array_equal(sid, shard_ids(keys, 4))
    assert set(np.unique(sid)) == {0, 1, 2, 3}
    # hash routing should be roughly balanced on sequential keys
    counts = np.bincount(sid, minlength=4)
    assert counts.min() > len(keys) // 8


@pytest.mark.parametrize("seed", range(3))
def test_sharded_matches_oracle(seed):
    t, o = ShardedSLSM(SMALL, n_shards=4), DictOracle()
    rng = np.random.default_rng(seed)
    for _ in range(6):
        n = int(rng.integers(1, 120))
        ks = rng.integers(0, 500, n).astype(np.int32)
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, 500, int(rng.integers(1, 16))).astype(np.int32)
        t.delete(dels)
        o.delete(dels)
    qs = np.arange(-4, 504, dtype=np.int32)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    k1, w1 = t.range(20, 480)
    k2, w2 = o.range(20, 480)
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(w1, w2)


def test_sharded_cascade_reaches_disk_levels():
    """Enough volume to force every shard through flushes and level spills."""
    t, o = ShardedSLSM(SMALL, n_shards=4), DictOracle()
    rng = np.random.default_rng(7)
    # 600 keys over a 800-key space: every shard (~150 keys) overflows its
    # memory buffer (R*Rn = 16) several times over, without exceeding the
    # tiny geometry's declared total capacity
    ks = rng.integers(0, 800, 600).astype(np.int32)
    vs = rng.integers(0, 100, 600).astype(np.int32)
    t.insert(ks, vs)
    o.insert(ks, vs)
    occ = t.shard_occupancy()
    assert (occ > 0).all()
    disk = sum(int(lv.counts.sum()) for lv in t.state.levels)
    assert disk > 0  # flush/cascade actually ran
    qs = rng.integers(-10, 810, 512).astype(np.int32)
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])


# -- back-compat facade ------------------------------------------------------

def test_core_slsm_facade_exports():
    from repro.core import slsm
    for name in ("SLSM", "SLSMState", "LevelState", "init_state",
                 "lookup_batch", "range_query", "merge_buffer_to_level0",
                 "merge_level_down", "compact_last_level", "ShardedSLSM"):
        assert hasattr(slsm, name), name
    from repro.core import SLSM as core_slsm
    from repro.engine import SLSM as engine_slsm
    assert core_slsm is engine_slsm
