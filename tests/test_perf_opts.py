"""Exactness guarantees for the §Perf beyond-paper optimizations:
grouped MoE routing and hierarchical sLSM block selection must be
bit-identical to their global counterparts (absent capacity overflow)."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import lsm_from_dense


def test_grouped_lsm_selection_exact(rng):
    cfg1 = replace(get_config("deepseek-7b").smoke(), lsm_dp_groups=1,
                   lsm_topk=2)
    cfg_g = replace(cfg1, lsm_dp_groups=4)
    params = lm.init_params(cfg1, jax.random.PRNGKey(0))
    b, s = 2, 96
    toks = jnp.asarray(rng.integers(0, cfg1.vocab, (b, s + 1)), jnp.int32)
    _, dense = lm.prefill_step(cfg1, params, {"tokens": toks[:, :s]})
    lsm = lsm_from_dense(cfg1, dense, s + 16)
    lg1, _ = lm.decode_step(cfg1, params, toks[:, s], lsm, kind="lsm")
    lgg, _ = lm.decode_step(cfg_g, params, toks[:, s], lsm, kind="lsm")
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lgg),
                               rtol=1e-5, atol=1e-5)


def test_grouped_moe_routing_exact(rng):
    """With no capacity drops, per-group routing == global routing."""
    cfg1 = get_config("qwen3-moe-30b-a3b").smoke()
    cfg_g = replace(cfg1, moe_dp_groups=2)
    params = lm.init_params(cfg1, jax.random.PRNGKey(1))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg1.vocab, (4, 16)),
                                   jnp.int32)}
    l1, _ = lm.logits_full(cfg1, params, batch)
    lg, _ = lm.logits_full(cfg_g, params, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(lg),
                               rtol=1e-5, atol=1e-5)


def test_grad_accumulation_matches_full_batch(rng):
    """accum_steps microbatching must reproduce the full-batch update
    (loss is mean-reduced, so grads are linear in microbatch means)."""
    from repro.train import adamw_init, make_train_step
    cfg = get_config("deepseek-7b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)),
                                   jnp.int32)}
    s1 = make_train_step(cfg, accum_steps=1)
    s4 = make_train_step(cfg, accum_steps=4)
    p1, _, m1 = s1(params, adamw_init(params), batch)
    p4, _, m4 = s4(params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-4)


def test_grouped_moe_train_step_finite(rng):
    from repro.train import adamw_init, make_train_step
    cfg = replace(get_config("granite-moe-1b-a400m").smoke(),
                  moe_dp_groups=2)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                   jnp.int32)}
    step = make_train_step(cfg)
    _, _, m = step(params, adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
