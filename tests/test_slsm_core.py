"""Engine behaviour vs the dict oracle (paper semantics: newest-wins,
tombstones, range, cascaded merges). The hypothesis interleaving
property lives in test_slsm_props.py; deterministic randomized-schedule
equivalents live in test_engine.py."""
import numpy as np
import pytest

from repro.core import SLSM, SLSMParams
from repro.core.oracle import DictOracle

TINY = SLSMParams(R=3, Rn=8, eps=0.02, D=2, m=0.5, mu=4, max_levels=3,
                  max_range=512)


def _check_lookups(t, o, qs):
    v1, f1 = t.lookup(qs)
    v2, f2 = o.lookup(qs)
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    v1s, f1s = t.lookup(qs, sparse=True)
    np.testing.assert_array_equal(f1s, f2)
    np.testing.assert_array_equal(v1s[f1s], v2[f2])


def test_newest_wins_update_in_place():
    """Paper 3.9.1: duplicate keys update in place in the active run."""
    t = SLSM(TINY)
    keys = np.zeros(64, np.int32) + 7
    vals = np.arange(64, dtype=np.int32)
    t.insert(keys, vals)
    v, f = t.lookup(np.asarray([7], np.int32))
    assert f[0] and v[0] == 63
    # dup-heavy stream must not have spilled: one distinct key
    assert t.n_levels == 0


def test_cascade_merge_and_depth():
    p = SLSMParams(R=2, Rn=8, eps=0.05, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=4096)
    t, o = SLSM(p), DictOracle()
    rng = np.random.default_rng(3)
    for _ in range(40):
        ks = rng.integers(0, 120, 16).astype(np.int32)
        vs = rng.integers(0, 9, 16).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
    assert t.n_levels >= 2  # cascade actually happened
    _check_lookups(t, o, np.arange(-2, 125, dtype=np.int32))


def test_tombstones_commit_at_deepest():
    p = SLSMParams(R=2, Rn=4, eps=0.05, D=2, m=1.0, mu=4, max_levels=3,
                   max_range=512)
    t = SLSM(p)
    ks = np.arange(16, dtype=np.int32)
    t.insert(ks, ks)
    t.delete(ks[:8])
    # force enough churn to push tombstones to the deepest level
    t.insert(ks + 100, ks)
    t.insert(ks + 200, ks)
    v, f = t.lookup(ks[:8])
    assert not f.any()
    v, f = t.lookup(ks[8:])
    assert f.all()


def test_range_truncation_bound():
    p = SLSMParams(R=4, Rn=64, eps=0.02, D=4, m=1.0, mu=32, max_levels=3,
                   max_range=512)
    t = SLSM(p)
    ks = np.arange(2000, dtype=np.int32)
    t.insert(ks, ks)
    k, v = t.range(0, 2000)
    assert len(k) == p.max_range  # static bound respected


def test_overflow_raises():
    p = SLSMParams(R=2, Rn=8, eps=0.05, D=2, m=1.0, mu=4, max_levels=2,
                   max_range=64)
    t = SLSM(p)
    with pytest.raises(RuntimeError, match="max_levels"):
        t.insert(np.arange(4000, dtype=np.int32),
                 np.arange(4000, dtype=np.int32))


def test_r_tradeoff_more_runs_fewer_merges():
    """Paper 3.1: higher R defers merges (fewer disk levels touched)."""
    rng = np.random.default_rng(0)
    ks = rng.integers(0, 2**20, 2000).astype(np.int32)
    vs = ks.copy()
    small = SLSM(SLSMParams(R=2, Rn=64, eps=0.01, D=4, m=1.0, mu=32,
                            max_levels=3, max_range=64))
    large = SLSM(SLSMParams(R=16, Rn=64, eps=0.01, D=4, m=1.0, mu=32,
                            max_levels=3, max_range=64))
    small.insert(ks, vs)
    large.insert(ks, vs)
    n_small = sum(int(lv.counts.sum()) for lv in small.state.levels)
    n_large = sum(int(lv.counts.sum()) for lv in large.state.levels)
    assert n_large < n_small  # more stays in memory with higher R
