# NOTE: do NOT set --xla_force_host_platform_device_count here.
# Smoke tests and benches must see 1 device; only launch/dryrun.py (its own
# process) and the subprocess tests force multi-device host platforms.
import gc

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True, scope="module")
def _release_jax_executables_between_modules():
    """Drop jax's compiled-executable caches after every test module.

    The jit cache is process-global and every compiled executable holds
    multiple memory mappings; a full suite run accumulates enough of
    them (each module compiles its own parameterizations) to hit the
    kernel's vm.max_map_count ceiling, at which point XLA's next mmap
    fails and the process segfaults mid-compile. Per-module clearing
    bounds the live-executable population while leaving within-module
    cache reuse (which the no-recompile assertions depend on) intact.
    """
    yield
    import jax

    jax.clear_caches()
    gc.collect()
