"""Z-set property sweep (ISSUE 8, DESIGN.md §13): arbitrary weighted
op interleavings (insert / delete / re-insert) vs the dict oracle, on
both drivers, probed mid-maintenance — plus weighted kernel-vs-ref
parity and batched-aggregate exactness.

The hypothesis `@given` sweeps activate when hypothesis is installed;
the seeded deterministic sweeps below always run (they drive the same
generators and checkers from fixed seeds), so the weighted algebra is
exercised even on a bare interpreter.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.oracle import DictOracle
from repro.core.params import KEY_EMPTY, SLSMParams, TuningPolicy
from repro.engine.engine import SLSM
from repro.engine.sharded import ShardedSLSM
from repro.kernels.heap_merge import heap_merge_op, heap_merge_ref
from repro.kernels.range_merge import range_merge_op, range_merge_ref

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

# small geometry: a few dozen ops cross seals, flushes, spills, and
# deepest-level compactions, so annihilation actually happens mid-test;
# merge_budget=1 paces the cascade so probes land mid-seal / mid-spill
# (the scheduler's backlog is live between ops), and the adaptive tuner
# may interleave RETUNE steps into the same backlog
PACED = SLSMParams(R=3, Rn=16, eps=0.02, D=2, m=0.5, mu=8, max_levels=3,
                   max_range=512, merge_budget=1,
                   tuning=TuningPolicy(mode="adaptive"))

KEYSPACE = 70
OP_KINDS = ("insert", "delete", "reinsert", "lookup", "range",
            "aggregate", "drain")


def _gen_ops(rng, n_ops=None):
    n = int(rng.integers(6, 29)) if n_ops is None else n_ops
    return [(OP_KINDS[int(rng.integers(0, len(OP_KINDS)))],
             int(rng.integers(1, 41))) for _ in range(n)]


def _probe(t, o, rng):
    qs = rng.integers(-5, KEYSPACE + 10, size=16).astype(np.int32)
    gv, gf = t.lookup_many(qs)
    wv, wf = o.lookup(qs)
    np.testing.assert_array_equal(np.asarray(gf), wf)
    np.testing.assert_array_equal(np.asarray(gv)[wf], wv[wf])


def _run_interleaving(t, ops_list, seed):
    """Drive one weighted interleaving through driver t and the oracle,
    checking every observable after every op (no drain barrier first —
    reads must be exact mid-backlog)."""
    rng = np.random.default_rng(seed)
    o = DictOracle()
    deleted = np.zeros(0, np.int32)
    for op, span in ops_list:
        if op == "insert":
            ks = rng.integers(0, KEYSPACE, size=span).astype(np.int32)
            vs = rng.integers(-(2**31), 2**31, size=ks.shape,
                              dtype=np.int64).astype(np.int32)
            t.insert(ks, vs); o.insert(ks, vs)
        elif op == "delete":
            ks = rng.integers(0, KEYSPACE,
                              size=span // 3 + 1).astype(np.int32)
            t.delete(ks); o.delete(ks)
            deleted = np.unique(np.concatenate([deleted, ks]))
        elif op == "reinsert":
            # resurrect previously-deleted keys: the -1 record must be
            # overridden by the newer +1 (delete does NOT poison a key)
            if deleted.size == 0:
                continue
            ks = deleted[:span].astype(np.int32)
            vs = rng.integers(0, 999, size=ks.shape).astype(np.int32)
            t.insert(ks, vs); o.insert(ks, vs)
        elif op == "lookup":
            _probe(t, o, rng)
        elif op == "range":
            lo = int(rng.integers(-5, KEYSPACE))
            k1, v1 = t.range(lo, lo + span)
            k2, v2 = o.range(lo, lo + span)
            np.testing.assert_array_equal(np.asarray(k1), k2)
            np.testing.assert_array_equal(np.asarray(v1), v2)
        elif op == "aggregate":
            lo = int(rng.integers(-5, KEYSPACE))
            want = o.aggregate(lo, lo + span)
            assert (t.count(lo, lo + span), t.sum(lo, lo + span)) == want
        else:
            t.drain()          # mid-stream merge barrier, then keep going
            _probe(t, o, rng)
    t.drain()
    _probe(t, o, rng)
    k1, v1 = t.range(-5, KEYSPACE + 10)
    k2, v2 = o.range(-5, KEYSPACE + 10)
    np.testing.assert_array_equal(np.asarray(k1), k2)
    np.testing.assert_array_equal(np.asarray(v1), v2)


def _make_driver(engine):
    return (SLSM(PACED) if engine == "single"
            else ShardedSLSM(PACED, n_shards=2))


@pytest.mark.parametrize("engine", ["single", "sharded"])
@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_weighted_interleavings_vs_oracle_seeded(engine, seed):
    rng = np.random.default_rng(seed)
    _run_interleaving(_make_driver(engine), _gen_ops(rng), seed + 1)


# -- weighted kernel-vs-ref parity -------------------------------------------

def _weighted_runs(rng, k, cap):
    K = np.full((k, cap), KEY_EMPTY, np.int32)
    V = np.zeros((k, cap), np.int32)
    W = np.zeros((k, cap), np.int8)
    S = np.zeros((k, cap), np.int32)
    seq = 0
    for r in range(k):
        n = int(rng.integers(0, cap + 1))
        kk = np.unique(rng.integers(0, 3 * cap, n)).astype(np.int32)
        n = len(kk)
        K[r, :n] = np.sort(kk)
        dels = rng.random(n) < 0.35
        V[r, :n] = np.where(dels, 0, rng.integers(-999, 999, n))
        W[r, :n] = np.where(dels, -1, 1)
        order = rng.permutation(n)
        S[r, :n] = seq + order
        seq += n
    return K, V, W, S


def _check_heap_merge_parity(k, cap, seed, drop):
    rng = np.random.default_rng(seed)
    K, V, W, S = _weighted_runs(rng, k, cap)
    args = (jnp.asarray(K), jnp.asarray(V), jnp.asarray(W), jnp.asarray(S))
    got = heap_merge_op(*args, drop)
    want = heap_merge_ref(*args, drop)
    for name, g, w in zip(("keys", "vals", "wts", "seqs", "count"),
                          got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} drop={drop}")


def _check_range_merge_parity(q, cap, seed, drop):
    rng = np.random.default_rng(seed)
    K = np.full((q, cap), KEY_EMPTY, np.int32)
    V = np.zeros((q, cap), np.int32)
    W = np.zeros((q, cap), np.int8)
    S = np.zeros((q, cap), np.int32)
    parts = int(rng.integers(1, 4))
    off = np.zeros((q, parts + 1), np.int32)
    seq = 0
    for qi in range(q):
        pos = 0
        for pi in range(parts):
            e = int(rng.integers(0, (cap - pos) // (parts - pi) + 1))
            K[qi, pos:pos + e] = np.sort(
                rng.integers(0, 50, e)).astype(np.int32)
            dels = rng.random(e) < 0.35
            V[qi, pos:pos + e] = np.where(dels, 0, rng.integers(0, 999, e))
            W[qi, pos:pos + e] = np.where(dels, -1, 1)
            S[qi, pos:pos + e] = np.arange(seq, seq + e)
            seq += e
            pos += e
            off[qi, pi + 1] = pos
    args = (jnp.asarray(K), jnp.asarray(V), jnp.asarray(W), jnp.asarray(S),
            jnp.asarray(off), drop)
    got = range_merge_op(*args)
    want = range_merge_ref(*args)
    for name, g, w in zip(("keys", "vals", "wts", "seqs", "keep"),
                          got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=f"{name} drop={drop}")


@pytest.mark.parametrize("k,cap,seed,drop", [
    (2, 24, 11, False), (4, 48, 12, True), (5, 16, 13, True),
])
def test_weighted_heap_merge_parity_seeded(k, cap, seed, drop):
    _check_heap_merge_parity(k, cap, seed, drop)


@pytest.mark.parametrize("q,cap,seed,drop", [
    (1, 32, 21, True), (3, 24, 22, False), (4, 40, 23, True),
])
def test_weighted_range_merge_parity_seeded(q, cap, seed, drop):
    _check_range_merge_parity(q, cap, seed, drop)


# -- batched aggregates vs the oracle ----------------------------------------

def _check_aggregates(seed, n_ranges, engine):
    rng = np.random.default_rng(seed)
    t, o = _make_driver(engine), DictOracle()
    for _ in range(4):
        ks = rng.integers(0, KEYSPACE, size=30).astype(np.int32)
        vs = rng.integers(-(2**31), 2**31, size=ks.shape,
                          dtype=np.int64).astype(np.int32)
        t.insert(ks, vs); o.insert(ks, vs)
        dk = rng.integers(0, KEYSPACE, size=8).astype(np.int32)
        t.delete(dk); o.delete(dk)
    ranges = []
    for _ in range(n_ranges):
        lo = int(rng.integers(-5, KEYSPACE))
        ranges.append((lo, lo + int(rng.integers(0, KEYSPACE))))
    cnt, tot, trunc = t.aggregate_many(ranges)
    assert not np.asarray(trunc).any()
    for i, (lo, hi) in enumerate(ranges):
        want_c, want_s = o.aggregate(lo, hi)
        assert (int(cnt[i]), int(tot[i])) == (want_c, want_s), (lo, hi)


@pytest.mark.parametrize("engine", ["single", "sharded"])
@pytest.mark.parametrize("seed,n_ranges", [(31, 1), (32, 5), (33, 9)])
def test_aggregate_many_matches_oracle_seeded(seed, n_ranges, engine):
    _check_aggregates(seed, n_ranges, engine)


# -- hypothesis sweeps (same checkers, adversarial generation) ---------------

if HAVE_HYPOTHESIS:
    ops_strategy = st.lists(
        st.tuples(st.sampled_from(OP_KINDS), st.integers(1, 40)),
        min_size=6, max_size=28)

    @settings(max_examples=12, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(ops=ops_strategy, seed=st.integers(0, 2**31 - 1))
    def test_weighted_interleavings_vs_oracle_single(ops, seed):
        _run_interleaving(SLSM(PACED), ops, seed)

    @settings(max_examples=6, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(ops=ops_strategy, seed=st.integers(0, 2**31 - 1))
    def test_weighted_interleavings_vs_oracle_sharded(ops, seed):
        _run_interleaving(ShardedSLSM(PACED, n_shards=2), ops, seed)

    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(k=st.integers(2, 5), cap=st.integers(4, 48),
           seed=st.integers(0, 2**31 - 1), drop=st.booleans())
    def test_weighted_heap_merge_kernel_matches_ref(k, cap, seed, drop):
        _check_heap_merge_parity(k, cap, seed, drop)

    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(q=st.integers(1, 4), cap=st.integers(2, 40),
           seed=st.integers(0, 2**31 - 1), drop=st.booleans())
    def test_weighted_range_merge_kernel_matches_ref(q, cap, seed, drop):
        _check_range_merge_parity(q, cap, seed, drop)

    @settings(max_examples=10, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(0, 2**31 - 1), n_ranges=st.integers(1, 9),
           engine=st.sampled_from(["single", "sharded"]))
    def test_aggregate_many_matches_oracle(seed, n_ranges, engine):
        _check_aggregates(seed, n_ranges, engine)
