"""Serving path: sLSM-tiered KV cache — sealing, selection, generation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import generate, lsm_from_dense, seal_hot_block


def _cfg():
    return get_config("deepseek-7b").smoke()


def test_lsm_decode_runs_and_is_close_to_dense(rng):
    """With topk >= n_blocks every block is attended: the tiered path must
    match the dense path exactly (the filter admits everything)."""
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 48  # 48 = 2 cold blocks of 16 + 16 hot
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 2)), jnp.int32)
    lg_ref, dense = lm.prefill_step(cfg, params, {"tokens": toks[:, :s]})

    grown = lm.init_decode_caches(cfg, b, s + 8, kind="dense")
    for kk in ("k", "v"):
        grown[kk] = grown[kk].at[:, :, :s].set(dense[kk])
    grown["pos"] = dense["pos"]
    lsm = lsm_from_dense(cfg, dense, s + 8)
    assert int(lsm["n_blocks"].reshape(-1)[0]) >= 2

    lg_d, _ = lm.decode_step(cfg, params, toks[:, s], grown, kind="dense")
    lg_l, _ = lm.decode_step(cfg, params, toks[:, s], lsm, kind="lsm")
    # topk(=2) == n_blocks(=2) -> exact
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_l),
                               rtol=2e-3, atol=2e-3)


def test_lsm_from_dense_exact_block_boundary(rng):
    """Prefill length an exact multiple of lsm_block is the edge case of
    the prefill->tiered conversion: the last full block must stay hot
    (>= 1 hot token, never an empty hot window) and the cold blocks +
    hot window must reproduce the dense K/V exactly, in token order."""
    cfg = _cfg()
    mu = cfg.lsm_block
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b = 2
    for n_blk in (1, 2, 3):
        s = n_blk * mu
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
        _, dense = lm.prefill_step(cfg, params, {"tokens": toks})
        lsm = lsm_from_dense(cfg, dense, s + 8)
        n_cold = int(lsm["n_blocks"].reshape(-1)[0])
        hot = int(lsm["hot_len"].reshape(-1)[0])
        assert n_cold == n_blk - 1, (s, n_cold)
        assert hot == mu, (s, hot)  # the boundary block lands hot, whole
        l, _, _, kv, hd = dense["k"].shape
        cold = np.asarray(lsm["blk_k"][:, :, :n_cold], np.float32).reshape(
            l, b, n_cold * mu, kv, hd)
        rebuilt = np.concatenate(
            [cold, np.asarray(lsm["hot_k"][:, :, :hot], np.float32)], axis=2)
        np.testing.assert_allclose(
            rebuilt, np.asarray(dense["k"], np.float32), rtol=1e-6, atol=1e-6)


def test_seal_preserves_attention(rng):
    """Sealing moves the oldest mu hot tokens into a cold block; with
    topk >= n_blocks every block stays attended, so the next-token
    attention output must be unchanged. Seal is only legitimate once the
    hot window holds >= mu tokens (as the serving loop guarantees), so we
    decode past mu first."""
    from dataclasses import replace
    cfg = replace(_cfg(), lsm_topk=8)   # admits all blocks post-seal
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 48
    mu = cfg.lsm_block
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + mu + 6)),
                       jnp.int32)
    _, dense = lm.prefill_step(cfg, params, {"tokens": toks[:, :s]})
    lsm = lsm_from_dense(cfg, dense, s + 2 * mu + 16)
    # decode until the hot window holds > mu tokens
    i = 0
    while int(lsm["hot_len"].reshape(-1)[0]) <= mu + 2:
        _, lsm = lm.decode_step(cfg, params, toks[:, s + i], lsm,
                                kind="lsm")
        i += 1
    probe = toks[:, s + i]
    lg_before, _ = lm.decode_step(cfg, params, probe, lsm, kind="lsm")
    sealed = seal_hot_block(cfg, lsm)
    assert (int(sealed["n_blocks"].reshape(-1)[0])
            == int(lsm["n_blocks"].reshape(-1)[0]) + 1)
    assert (int(sealed["hot_len"].reshape(-1)[0])
            == int(lsm["hot_len"].reshape(-1)[0]) - mu)
    lg_sealed, _ = lm.decode_step(cfg, params, probe, sealed, kind="lsm")
    np.testing.assert_allclose(np.asarray(lg_before), np.asarray(lg_sealed),
                               rtol=2e-3, atol=2e-3)


def test_generate_dense_and_lsm(rng):
    cfg = _cfg()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 24)),
                                    jnp.int32)}
    toks_d, _ = generate(cfg, params, prompt, steps=6, kind="dense")
    toks_l, _ = generate(cfg, params, prompt, steps=6, kind="lsm",
                         max_len=128)
    assert toks_d.shape == (2, 6) and toks_l.shape == (2, 6)
    # same first token (prefill path identical)
    np.testing.assert_array_equal(np.asarray(toks_d[:, 0]),
                                  np.asarray(toks_l[:, 0]))


def test_generate_ssm(rng):
    cfg = get_config("mamba2-370m").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                    jnp.int32)}
    toks, caches = generate(cfg, params, prompt, steps=5, kind="dense")
    assert toks.shape == (2, 5)
    assert np.isfinite(np.asarray(caches["ssm"], np.float32)).all()
