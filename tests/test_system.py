"""End-to-end behaviour tests for the full system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.slsm_paper import paper_params
from repro.core import SLSM
from repro.core.oracle import DictOracle
from repro.data import TokenStream, make_kv_workload
from repro.models import lm
from repro.train import adamw_init, make_train_step


def test_paper_baseline_params_e2e():
    """The paper's tuned parameter set, scaled-down dataset: full
    insert -> merge -> lookup -> range -> delete lifecycle."""
    p = paper_params(R=6, Rn=128, D=4, mu=32, max_levels=3, max_range=8192)
    t, o = SLSM(p), DictOracle()
    w = make_kv_workload("uniform", 20000, seed=0, key_space=2**22)
    t.insert(w.keys, w.vals)
    o.insert(w.keys, w.vals)
    v1, f1 = t.lookup(w.lookups[:2048])
    v2, f2 = o.lookup(w.lookups[:2048])
    np.testing.assert_array_equal(f1, f2)
    np.testing.assert_array_equal(v1[f1], v2[f2])
    t.delete(w.keys[:512])
    o.delete(w.keys[:512])
    v1, f1 = t.lookup(w.keys[:512])
    assert not f1.any()
    k1, _ = t.range(0, 2**18)
    k2, _ = o.range(0, 2**18)
    np.testing.assert_array_equal(k1, k2)


def test_workload_generators_shapes():
    for kind in ("uniform", "normal", "zipf", "cluster-lookup"):
        w = make_kv_workload(kind, 1000, seed=1, lookup_frac=0.3)
        assert w.keys.shape == (1000,) and w.lookups.shape == (300,)
        assert w.keys.dtype == np.int32


def test_token_stream_determinism_and_sharding():
    a = next(iter(TokenStream(1000, 8, 16, seed=3, host_id=0, n_hosts=2)))
    b = next(iter(TokenStream(1000, 8, 16, seed=3, host_id=0, n_hosts=2)))
    c = next(iter(TokenStream(1000, 8, 16, seed=3, host_id=1, n_hosts=2)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not (a["tokens"] == c["tokens"]).all()
    assert a["tokens"].shape == (4, 16)


def test_train_driver_few_steps():
    """The (b) deliverable driver path: stream -> train steps -> loss."""
    cfg = get_config("granite-moe-1b-a400m").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, base_lr=1e-3, warmup=2))
    stream = iter(TokenStream(cfg.vocab, 4, 32, seed=0))
    losses = []
    for _ in range(4):
        batch = next(stream)
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in batch.items()})
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), losses
