"""HeapMerge equivalents: sort-based, rank-based, and the Pallas
tournament all agree (paper Algorithm 1 semantics over weighted
records, DESIGN.md §13). The hypothesis sweep lives in
test_merge_props.py; the seeded agreement test here keeps cross-path
coverage when hypothesis is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import runs as RU
from repro.core.params import KEY_EMPTY
from repro.kernels.heap_merge import heap_merge_op


def make_runs(rng, k, cap, dup_rate=0.3, del_rate=0.15):
    ks, vs, ws, ss = [], [], [], []
    seq = 0
    for _ in range(k):
        n = int(rng.integers(1, cap + 1))
        pool = rng.integers(0, int(cap * k * (1 - dup_rate) + 2), n)
        kk = np.unique(pool).astype(np.int32)
        n = len(kk)
        run_k = np.full(cap, KEY_EMPTY, np.int32)
        run_k[:n] = np.sort(kk)
        run_v = np.zeros(cap, np.int32)
        run_v[:n] = rng.integers(-50, 50, n)
        run_w = np.zeros(cap, np.int8)
        run_w[:n] = 1
        dels = rng.random(n) < del_rate       # weight -1: a retraction
        run_w[:n][dels] = -1
        run_v[:n][dels] = 0                   # deletes carry no payload
        run_s = np.zeros(cap, np.int32)
        order = rng.permutation(n)  # seqs not aligned with key order
        run_s[:n] = seq + order
        seq += n
        ks.append(run_k); vs.append(run_v); ws.append(run_w); ss.append(run_s)
    return (jnp.asarray(np.stack(ks)), jnp.asarray(np.stack(vs)),
            jnp.asarray(np.stack(ws)), jnp.asarray(np.stack(ss)))


def oracle_merge(K, V, W, S, drop):
    items = {}
    best_seq = {}
    for r in range(K.shape[0]):
        for i in range(K.shape[1]):
            key = int(K[r, i])
            if key == int(KEY_EMPTY):
                continue
            if key not in best_seq or int(S[r, i]) > best_seq[key]:
                best_seq[key] = int(S[r, i])
                items[key] = (int(V[r, i]), int(W[r, i]), int(S[r, i]))
    # newest-wins; drop_annihilated elides keys whose surviving weight
    # is <= 0 (the delete commits — paper 2.5/2.8 recast as Z-sets)
    out = sorted((k, v, w, s) for k, (v, w, s) in items.items()
                 if not (drop and w <= 0))
    return out


@pytest.mark.parametrize("k,cap,seed,drop", [
    (2, 16, 0, False), (3, 64, 1, True), (5, 96, 2, False), (4, 64, 3, True),
])
def test_merge_paths_agree_seeded(k, cap, seed, drop):
    rng = np.random.default_rng(seed)
    K, V, W, S = make_runs(rng, k, cap)
    expect = oracle_merge(np.asarray(K), np.asarray(V), np.asarray(W),
                          np.asarray(S), drop)

    for fn in (RU.merge_runs, RU.merge_kway_ranked, heap_merge_op):
        mk, mv, mw, ms, cnt = fn(K, V, W, S, drop)
        got = list(zip(np.asarray(mk)[:int(cnt)].tolist(),
                       np.asarray(mv)[:int(cnt)].tolist(),
                       np.asarray(mw)[:int(cnt)].tolist(),
                       np.asarray(ms)[:int(cnt)].tolist()))
        assert got == expect, fn.__name__


def test_merge_keeps_order_and_padding():
    rng = np.random.default_rng(1)
    K, V, W, S = make_runs(rng, 3, 32)
    mk, mv, mw, ms, cnt = RU.merge_runs(K, V, W, S, False)
    n = int(cnt)
    arr = np.asarray(mk)
    assert (np.diff(arr[:n]) > 0).all()          # strictly sorted, unique
    assert (arr[n:] == KEY_EMPTY).all()          # compacted padding
    assert (np.asarray(mw)[:n] != 0).all()       # survivors carry weight
    assert (np.asarray(mw)[n:] == 0).all()       # padding weight-neutral


def test_annihilation_drops_matched_pairs():
    """An insert and its retraction (newer seq) cancel under drop=True:
    the key vanishes and the count shrinks by both rows."""
    cap = 8
    K = np.full((2, cap), KEY_EMPTY, np.int32)
    V = np.zeros((2, cap), np.int32)
    W = np.zeros((2, cap), np.int8)
    S = np.zeros((2, cap), np.int32)
    K[0, :3] = [5, 9, 12]; V[0, :3] = [50, 90, 120]; W[0, :3] = 1
    S[0, :3] = [0, 1, 2]
    K[1, :2] = [9, 30]; V[1, :2] = [0, 300]
    W[1, :2] = [-1, 1]; S[1, :2] = [3, 4]
    args = (jnp.asarray(K), jnp.asarray(V), jnp.asarray(W), jnp.asarray(S))
    mk, mv, mw, ms, cnt = RU.merge_runs(*args, True)
    assert int(cnt) == 3
    assert np.asarray(mk)[:3].tolist() == [5, 12, 30]
    assert np.asarray(mv)[:3].tolist() == [50, 120, 300]
    # without drop the retraction survives (negative weight propagates
    # until a merge creates the deepest data)
    mk, mv, mw, ms, cnt = RU.merge_runs(*args, False)
    assert int(cnt) == 4
    assert np.asarray(mk)[:4].tolist() == [5, 9, 12, 30]
    assert np.asarray(mw)[:4].tolist() == [1, -1, 1, 1]
