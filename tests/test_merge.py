"""HeapMerge equivalents: sort-based, rank-based, and the Pallas
tournament all agree (paper Algorithm 1 semantics). The hypothesis
sweep lives in test_merge_props.py; the seeded agreement test here
keeps cross-path coverage when hypothesis is absent."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import runs as RU
from repro.core.params import KEY_EMPTY, TOMBSTONE
from repro.kernels.heap_merge import heap_merge_op


def make_runs(rng, k, cap, dup_rate=0.3):
    ks, vs, ss = [], [], []
    seq = 0
    for _ in range(k):
        n = int(rng.integers(1, cap + 1))
        pool = rng.integers(0, int(cap * k * (1 - dup_rate) + 2), n)
        kk = np.unique(pool).astype(np.int32)
        n = len(kk)
        run_k = np.full(cap, KEY_EMPTY, np.int32)
        run_k[:n] = np.sort(kk)
        run_v = np.zeros(cap, np.int32)
        run_v[:n] = rng.integers(-50, 50, n)
        run_v[:n][rng.random(n) < 0.15] = TOMBSTONE
        run_s = np.zeros(cap, np.int32)
        order = rng.permutation(n)  # seqs not aligned with key order
        run_s[:n] = seq + order
        seq += n
        ks.append(run_k); vs.append(run_v); ss.append(run_s)
    return (jnp.asarray(np.stack(ks)), jnp.asarray(np.stack(vs)),
            jnp.asarray(np.stack(ss)))


def oracle_merge(K, V, S, drop):
    items = {}
    best_seq = {}
    for r in range(K.shape[0]):
        for i in range(K.shape[1]):
            key = int(K[r, i])
            if key == int(KEY_EMPTY):
                continue
            if key not in best_seq or int(S[r, i]) > best_seq[key]:
                best_seq[key] = int(S[r, i])
                items[key] = (int(V[r, i]), int(S[r, i]))
    out = sorted((k, v, s) for k, (v, s) in items.items()
                 if not (drop and v == int(TOMBSTONE)))
    return out


@pytest.mark.parametrize("k,cap,seed,drop", [
    (2, 16, 0, False), (3, 64, 1, True), (5, 96, 2, False), (4, 64, 3, True),
])
def test_merge_paths_agree_seeded(k, cap, seed, drop):
    rng = np.random.default_rng(seed)
    K, V, S = make_runs(rng, k, cap)
    expect = oracle_merge(np.asarray(K), np.asarray(V), np.asarray(S), drop)

    for fn in (RU.merge_runs, RU.merge_kway_ranked, heap_merge_op):
        mk, mv, ms, cnt = fn(K, V, S, drop)
        got = list(zip(np.asarray(mk)[:int(cnt)].tolist(),
                       np.asarray(mv)[:int(cnt)].tolist(),
                       np.asarray(ms)[:int(cnt)].tolist()))
        assert got == expect, fn.__name__


def test_merge_keeps_order_and_padding():
    rng = np.random.default_rng(1)
    K, V, S = make_runs(rng, 3, 32)
    mk, mv, ms, cnt = RU.merge_runs(K, V, S, False)
    n = int(cnt)
    arr = np.asarray(mk)
    assert (np.diff(arr[:n]) > 0).all()          # strictly sorted, unique
    assert (arr[n:] == KEY_EMPTY).all()          # compacted padding
