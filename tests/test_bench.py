"""The workload/benchmark subsystem (repro.bench).

Covers the ISSUE-2 contract: generator determinism under a fixed seed,
zipfian skew sanity (top-1% of the key universe receives the analytically
expected mass), batched-vs-scalar lookup equivalence on both backends,
scenario selector resolution, and the BENCH_*.json schema round trip
through the runner.
"""
from __future__ import annotations

import json

import numpy as np
import pytest

from repro.bench import schema as SCH
from repro.bench.scenarios import SCENARIOS, Scenario, scenarios_for
from repro.bench.workloads import (WORKLOAD_FAMILIES, make_workload,
                                   zipf_expected_top_mass)
from repro.core.params import SLSMParams
from repro.engine import SLSM, ShardedSLSM

FAMILIES = sorted(WORKLOAD_FAMILIES)

TINY = dict(R=2, Rn=16, D=2, mu=8, max_levels=3, eps=1e-3)


# --------------------------------------------------------------------------
# generators
# --------------------------------------------------------------------------

def _stream_sig(w):
    """Order-sensitive signature of a serving stream (for determinism
    comparisons): one (client, kind, keys, vals) tuple per request."""
    return [(r.client, r.kind, r.keys.tolist(), r.vals.tolist())
            for r in w.requests]


@pytest.mark.parametrize("kind", FAMILIES)
def test_generator_deterministic_under_fixed_seed(kind):
    a = make_workload(kind, 2_000, seed=7)
    b = make_workload(kind, 2_000, seed=7)
    c = make_workload(kind, 2_000, seed=8)
    if kind == "serving":       # request stream, not phase arrays
        assert _stream_sig(a) == _stream_sig(b)
        assert _stream_sig(a) != _stream_sig(c)
        return
    for f in ("keys", "vals", "lookups", "deletes", "ranges", "absent"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert not np.array_equal(a.keys, c.keys)


@pytest.mark.parametrize("kind", FAMILIES)
def test_inserted_keys_even_absent_odd(kind):
    w = make_workload(kind, 1_000, seed=3)
    if kind == "serving":
        writes = np.concatenate([r.keys for r in w.requests
                                 if r.kind in ("insert", "delete")])
        assert (writes % 2 == 0).all()
        assert (w.absent % 2 == 1).all()
        assert not np.isin(w.absent, writes).any()
        assert any(r.kind == "lookup" for r in w.requests)
        return
    assert (w.keys % 2 == 0).all()
    assert (w.absent % 2 == 1).all()
    assert not np.isin(w.absent, w.keys).any()
    assert w.vals.shape == w.keys.shape
    assert len(w.lookups) > 0


def test_zipf_top1pct_mass_matches_analytic():
    universe, theta = 10_000, 1.1
    w = make_workload("zipfian", 50_000, seed=1, universe=universe,
                      theta=theta)
    counts = np.sort(np.unique(w.keys, return_counts=True)[1])[::-1]
    top = max(1, universe // 100)
    measured = counts[:top].sum() / len(w.keys)
    expected = zipf_expected_top_mass(universe, theta)
    assert abs(measured - expected) < 0.05, (measured, expected)
    assert measured > 5 * 0.01          # way above the uniform 1% share


def test_sequential_keys_monotone():
    w = make_workload("sequential", 500, seed=2)
    assert (np.diff(w.keys.astype(np.int64)) > 0).all()


def test_delete_heavy_deletes_are_inserted_keys():
    w = make_workload("delete-heavy", 1_000, seed=4)
    assert len(w.deletes) > 0
    assert np.isin(w.deletes, w.keys).all()
    assert len(np.unique(w.deletes)) == len(w.deletes)


def test_range_scan_windows_well_formed():
    w = make_workload("range-scan", 1_000, seed=5)
    assert w.ranges.shape[1] == 2 and len(w.ranges) > 0
    assert (w.ranges[:, 0] < w.ranges[:, 1]).all()


def test_unknown_family_raises():
    with pytest.raises(ValueError, match="unknown workload family"):
        make_workload("nope", 10)


# --------------------------------------------------------------------------
# batched lookup fast path == scalar path, on both backends
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_batched_vs_scalar_lookup_equivalence(backend):
    t = SLSM(SLSMParams(backend=backend, **TINY))
    w = make_workload("uniform", 300, seed=5, key_space=2**20)
    t.insert(w.keys, w.vals)
    t.delete(w.keys[:7])
    qs = np.concatenate([w.keys[:20], w.absent[:8]])
    vm, fm = t.lookup_many(qs)
    for i, k in enumerate(qs):
        v1, f1 = t.lookup(np.asarray([k]))
        assert f1[0] == fm[i], k
        if fm[i]:
            assert v1[0] == vm[i], k


def test_lookup_many_odd_sizes_and_empty():
    t = SLSM(SLSMParams(**TINY))
    w = make_workload("uniform", 200, seed=9, key_space=2**20)
    t.insert(w.keys, w.vals)
    ref_v, ref_f = t.lookup(w.keys)          # exact-shape baseline
    for q in (1, 3, 17, 64, 129):
        v, f = t.lookup_many(w.keys[:q])
        assert np.array_equal(v, ref_v[:q]) and np.array_equal(f, ref_f[:q])
    v, f = t.lookup_many(np.zeros(0, np.int32))
    assert v.shape == (0,) and f.shape == (0,)


def test_sharded_lookup_many_matches_oracle():
    s = ShardedSLSM(SLSMParams(**TINY), n_shards=3)
    w = make_workload("uniform", 400, seed=6, key_space=2**20)
    s.insert(w.keys, w.vals)
    oracle = dict(zip(w.keys.tolist(), w.vals.tolist()))  # last write wins
    qs = np.concatenate([w.keys[:30], w.absent[:10]])
    vm, fm = s.lookup_many(qs)
    for i, k in enumerate(qs.tolist()):
        assert bool(fm[i]) == (k in oracle), k
        if fm[i]:
            assert vm[i] == oracle[k], k


def test_maintenance_counters_track_merges():
    t = SLSM(SLSMParams(**TINY))
    w = make_workload("uniform", 400, seed=11, key_space=2**20)
    t.insert(w.keys, w.vals)
    assert t.stats["seals"] > 0 and t.stats["flushes"] > 0
    s = ShardedSLSM(SLSMParams(**TINY), n_shards=2)
    s.insert(w.keys, w.vals)
    assert s.stats["seals"] > 0


# --------------------------------------------------------------------------
# scenarios + runner + schema
# --------------------------------------------------------------------------

def test_scenarios_for_selectors():
    assert [s.name for s in scenarios_for("all")] == [
        "uniform", "sequential", "zipfian", "delete_heavy", "range_scan",
        "shifting", "serving", "replication"]
    sweep = scenarios_for("sweep-R")
    assert all(s.name.startswith("sweep_R") for s in sweep)
    mixed = scenarios_for("uniform,sweep-policy,uniform")
    assert [s.name for s in mixed] == [
        "uniform", "sweep_policy_tiering", "sweep_policy_leveling"]
    with pytest.raises(ValueError, match="unknown scenario selector"):
        scenarios_for("nope")
    assert all(sc.name in SCENARIOS for sc in scenarios_for("sweeps"))


@pytest.fixture(scope="module")
def bench_doc(tmp_path_factory):
    from repro.bench.runner import run_scenario

    out = tmp_path_factory.mktemp("bench")
    path, doc = run_scenario(Scenario("uniform", "uniform"), out,
                             profile="smoke")
    return path, doc


def test_runner_emits_schema_valid_bench(bench_doc):
    path, doc = bench_doc
    assert path.name == "BENCH_uniform.json"
    on_disk = json.loads(path.read_text())
    assert SCH.validate(on_disk) == []
    assert on_disk["schema_version"] == SCH.SCHEMA_VERSION
    m = on_disk["metrics"]
    assert m["insert"]["ops"] > 0
    assert m["lookup_batched"]["ops"] > 0
    assert m["batched_speedup"] > 0
    assert m["maintenance"]["seals"] > 0
    assert 0 <= m["bloom"]["fp_rate_measured"] <= 1


def test_sweep_merge_budget_family_and_canonical_default():
    """ISSUE-3: the canonical trajectory runs the incremental scheduler
    (merge_budget=1); the sweep family keeps the synchronous baseline
    (budget 0) and the pacing axis measured."""
    sweep = scenarios_for("sweep-merge-budget")
    budgets = [s.engine_params().merge_budget for s in sweep]
    assert budgets == [0, 1, 2, 4]
    assert all(s.name.startswith("sweep_merge_budget") for s in sweep)
    for s in scenarios_for("all"):
        assert s.engine_params().merge_budget == 1, s.name


def test_schema_requires_stall_metrics(bench_doc):
    """SCHEMA_VERSION 2: insert p999/max_stall and maintenance backlog
    are mandatory — a document without them is not a valid trajectory
    point anymore."""
    _, doc = bench_doc
    m = doc["metrics"]
    assert m["insert"]["p999_us"] >= m["insert"]["p99_us"] >= 0
    assert m["insert"]["max_stall_us"] >= m["insert"]["p999_us"]
    assert m["maintenance"]["backlog_peak"] >= 0
    assert doc["engine"]["merge_budget"] == 1   # canonical default

    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["insert"]["p999_us"]
    assert any("p999_us" in e for e in SCH.validate(bad))
    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["insert"]["max_stall_us"]
    assert any("max_stall_us" in e for e in SCH.validate(bad))
    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["maintenance"]["backlog_peak"]
    assert any("backlog_peak" in e for e in SCH.validate(bad))
    bad = json.loads(json.dumps(doc))
    del bad["engine"]["merge_budget"]
    assert any("merge_budget" in e for e in SCH.validate(bad))


def test_schema_rejects_malformed_documents(bench_doc):
    _, doc = bench_doc
    assert SCH.validate(doc) == []

    bad = json.loads(json.dumps(doc))
    bad["schema_version"] = 99
    assert any("schema_version" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["lookup_batched"]
    assert any("lookup_batched" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["insert"]["ops"] = 0
    assert any("insert.ops" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["bloom"]["fp_rate_measured"] = 2.0
    assert any("fp_rate_measured" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    bad["profile"]["insert_steady_state"] = "yes"
    assert any("insert_steady_state" in e for e in SCH.validate(bad))

    assert SCH.validate([]) and SCH.validate(None)


def test_schema_v6_durability_block(bench_doc):
    """SCHEMA_VERSION 6+: metrics.durability is a required (nullable)
    key from v6 on — committed v5 trajectory points predate the WAL
    and stay valid."""
    _, doc = bench_doc
    assert doc["schema_version"] == SCH.SCHEMA_VERSION
    assert doc["metrics"]["durability"] is None   # WAL-off run

    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["durability"]
    assert any("durability" in e for e in SCH.validate(bad))
    # the same document labeled v5 is exempt (compat window)
    bad["schema_version"] = 5
    assert SCH.validate(bad) == []

    good = json.loads(json.dumps(doc))
    good["metrics"]["durability"] = {
        "wal_bytes": 1 << 20, "wal_records": 128,
        "wal_bytes_per_op": 8.4, "snapshot_ms": 12.5, "restore_ms": 80.0,
        "replayed_chunks": 128, "fsync": True}
    assert SCH.validate(good) == []
    good["metrics"]["durability"]["restore_ms"] = -1.0
    assert any("restore_ms" in e for e in SCH.validate(good))
    good["metrics"]["durability"]["restore_ms"] = 80.0
    good["metrics"]["durability"]["wal_records"] = 0
    assert any("wal_records" in e for e in SCH.validate(good))


def test_schema_v7_zset_block(bench_doc):
    """SCHEMA_VERSION 7: metrics.zset (weighted-merge telemetry,
    DESIGN.md §13) is a required key whose counters must form a
    consistent ledger — annihilated == in − out, out ≤ in, nothing
    negative. v5/v6 documents predate the weighted algebra and are
    exempt (compat window)."""
    _, doc = bench_doc
    zs = doc["metrics"]["zset"]
    assert zs["rows_merged_in"] >= zs["rows_merged_out"] >= 0
    assert (zs["rows_annihilated"]
            == zs["rows_merged_in"] - zs["rows_merged_out"])

    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["zset"]
    assert any("zset" in e for e in SCH.validate(bad))
    # the same document labeled v6 predates the block and is exempt
    bad["schema_version"] = 6
    assert SCH.validate(bad) == []

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["zset"]["rows_annihilated"] += 1
    assert any("rows_annihilated" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["zset"]["rows_merged_out"] = (
        bad["metrics"]["zset"]["rows_merged_in"] + 1)
    assert any("rows_merged_out" in e for e in SCH.validate(bad))

    bad = json.loads(json.dumps(doc))
    bad["metrics"]["zset"]["ghost_payload_bytes_skipped"] = -4
    assert any("ghost_payload_bytes_skipped" in e
               for e in SCH.validate(bad))


def test_schema_v8_replication_block(bench_doc):
    """SCHEMA_VERSION 8: metrics.replication is a required (nullable)
    key — null on scenarios that attach no followers, a full
    lag/failover ledger on the `replication` scenario. v5-v7 documents
    predate the layer and are exempt (compat window)."""
    _, doc = bench_doc
    assert doc["schema_version"] == SCH.SCHEMA_VERSION
    assert doc["metrics"]["replication"] is None  # no followers attached

    bad = json.loads(json.dumps(doc))
    del bad["metrics"]["replication"]
    assert any("replication" in e for e in SCH.validate(bad))
    # the same document labeled v7 predates the block and is exempt
    bad["schema_version"] = 7
    assert SCH.validate(bad) == []

    good = json.loads(json.dumps(doc))
    good["schema_version"] = 8          # the pre-self-healing ledger
    good["metrics"]["replication"] = {
        "followers": 2, "shipped_records": 104, "shipped_bytes": 54_000,
        "lag_records_peak": 26, "lag_records_final": 0,
        "lag_bytes_final": 0, "apply_ops_per_s": 85.4,
        "failover_ms": 941.0, "promoted_exact": True}
    assert SCH.validate(good) == []
    good["metrics"]["replication"]["shipped_records"] = 0
    assert any("shipped_records" in e for e in SCH.validate(good))
    good["metrics"]["replication"]["shipped_records"] = 104
    good["metrics"]["replication"]["lag_records_final"] = -1
    assert any("lag_records_final" in e for e in SCH.validate(good))
    good["metrics"]["replication"]["lag_records_final"] = 0
    good["metrics"]["replication"]["promoted_exact"] = "yes"
    assert any("promoted_exact" in e for e in SCH.validate(good))
    del good["metrics"]["replication"]["promoted_exact"]
    del good["metrics"]["replication"]["failover_ms"]
    assert any("failover_ms" in e for e in SCH.validate(good))


def test_schema_v9_selfheal_keys(bench_doc):
    """SCHEMA_VERSION 9: the replication block additionally carries the
    self-healing ledger — failover_auto_ms / rpo_records /
    wal_pruned_bytes / lease_expiries — with a lease expiry required
    (the scenario must actually run the automatic-failover act). A v8
    document without them stays valid (compat window)."""
    _, doc = bench_doc
    good = json.loads(json.dumps(doc))
    rep = {
        "followers": 2, "shipped_records": 104, "shipped_bytes": 54_000,
        "lag_records_peak": 26, "lag_records_final": 0,
        "lag_bytes_final": 0, "apply_ops_per_s": 85.4,
        "failover_ms": 941.0, "promoted_exact": True,
        "failover_auto_ms": 211.5, "rpo_records": 0,
        "wal_pruned_bytes": 9520, "lease_expiries": 1}
    good["metrics"]["replication"] = rep
    assert SCH.validate(good) == []
    for key in ("failover_auto_ms", "rpo_records", "wal_pruned_bytes",
                "lease_expiries"):
        bad = json.loads(json.dumps(good))
        del bad["metrics"]["replication"][key]
        assert any(key in e for e in SCH.validate(bad)), key
    bad = json.loads(json.dumps(good))
    bad["metrics"]["replication"]["rpo_records"] = -1
    assert any("rpo_records" in e for e in SCH.validate(bad))
    bad = json.loads(json.dumps(good))
    bad["metrics"]["replication"]["lease_expiries"] = 0
    assert any("lease_expiries" in e for e in SCH.validate(bad))
    # the same block labeled v8 predates the self-healing keys
    v8 = json.loads(json.dumps(good))
    v8["schema_version"] = 8
    for key in ("failover_auto_ms", "rpo_records", "wal_pruned_bytes",
                "lease_expiries"):
        del v8["metrics"]["replication"][key]
    assert SCH.validate(v8) == []


def test_sweep_durability_family():
    """The durability sweep isolates the WAL axis: identical uniform
    points, one logging + fsyncing, one not."""
    sweep = scenarios_for("sweep-durability")
    assert [s.name for s in sweep] == ["sweep_durability_wal",
                                      "sweep_durability_off"]
    assert [s.durability for s in sweep] == [True, False]
    on, off = sweep
    assert on.engine_params() == off.engine_params()


def test_runner_emits_durability_block(tmp_path):
    """A WAL-on smoke run emits a validating metrics.durability block
    whose restore replayed every logged write chunk (restore is timed
    before the snapshot exists)."""
    from repro.bench.runner import run_scenario

    path, doc = run_scenario(SCENARIOS["sweep_durability_wal"], tmp_path,
                             profile="smoke")
    assert SCH.validate(doc) == []
    dur = doc["metrics"]["durability"]
    assert dur is not None and dur["fsync"] is True
    assert dur["wal_records"] > 0
    assert dur["replayed_chunks"] > 0
    assert dur["wal_bytes_per_op"] > 0
    assert dur["restore_ms"] > 0 and dur["snapshot_ms"] > 0
