"""REQUIRED per-arch smoke tests: reduced same-family config, one forward
+ one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config
from repro.models import lm
from repro.train import adamw_init, make_train_step


def _batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        batch["positions3"] = jnp.broadcast_to(pos[None], (3, b, s))
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_arch_smoke_forward_and_train(arch, rng):
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)

    logits, aux = lm.logits_full(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    step = make_train_step(cfg)
    params2, opt2, metrics = step(params, adamw_init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    moved = jax.tree.map(
        lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-moe-30b-a3b",
                                  "mamba2-370m", "zamba2-1.2b",
                                  "whisper-tiny", "qwen2-vl-7b"])
def test_decode_matches_forward(arch, rng):
    """Teacher forcing: prefill + cached decode == full forward."""
    cfg = get_config(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 3)), jnp.int32)
    batch = _batch(cfg, rng, b, s)
    batch["tokens"] = toks[:, :s]
    full = dict(batch, tokens=toks[:, : s + 2])
    if cfg.mrope:
        pos = jnp.broadcast_to(jnp.arange(s + 2)[None, :], (b, s + 2))
        full["positions3"] = jnp.broadcast_to(pos[None], (3, b, s + 2))
    ref_logits, _ = lm.logits_full(cfg, params, full)

    batch.pop("labels")
    if cfg.mrope:
        batch.pop("positions3")  # text default positions == M-RoPE equal streams
        full.pop("positions3")
        ref_logits, _ = lm.logits_full(cfg, params, full)
    lg, caches = lm.prefill_step(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(lg),
                               np.asarray(ref_logits[:, s - 1]),
                               rtol=2e-3, atol=2e-3)
    # grow dense caches for decode room
    caches = dict(caches)
    for kk in ("k", "v"):
        if kk in caches:
            L, B_, s_, KV, hd = caches[kk].shape
            caches[kk] = jnp.zeros((L, B_, s + 8, KV, hd),
                                   caches[kk].dtype).at[:, :, :s_].set(caches[kk])
    if "shared" in caches and "k" in caches["shared"]:
        sh = {}
        for kk in ("k", "v"):
            A, B_, s_, KV, hd = caches["shared"][kk].shape
            sh[kk] = jnp.zeros((A, B_, s + 8, KV, hd),
                               caches["shared"][kk].dtype
                               ).at[:, :, :s_].set(caches["shared"][kk])
        caches["shared"] = sh
    for i in range(2):
        lg, caches = lm.decode_step(cfg, params, toks[:, s + i], caches,
                                    kind="dense")
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(ref_logits[:, s + i]),
                                   rtol=2e-3, atol=2e-3)


def test_mrope_equals_rope_for_text(rng):
    """For text (equal position streams) M-RoPE must reduce to RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8)[None, :], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6,
                               atol=1e-6)


def test_train_loss_decreases(rng):
    """Tiny end-to-end training sanity: loss drops on a repeated batch."""
    cfg = get_config("deepseek-7b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, base_lr=3e-3, warmup=2),
                   static_argnums=())
    opt = adamw_init(params)
    batch = _batch(cfg, rng, 4, 32)
    losses = []
    for _ in range(8):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses
