"""Fixtures for the durability suite (the machinery lives in
harness.py so test modules can import it flatly — the tests directory
is not a package)."""
import pytest

from harness import CrashHarness


@pytest.fixture(scope="module")
def harness(tmp_path_factory):
    """Module-scoped `CrashHarness` (reference runs and oracles are
    shared across every crash point in the module)."""
    return CrashHarness(tmp_path_factory)
