"""Crash-point injection harness for the durability layer (DESIGN.md §12).

The machinery these tests share:

  * a deterministic mixed insert/delete op stream where each op is
    exactly one driver call — and therefore exactly one WAL WRITE
    record, so the j-th WRITE record in the log corresponds to the j-th
    op of the stream;
  * a reference run with durability on, which yields the final WAL and
    the byte extents of every record (`wal.record_offsets`) — the map
    of legal crash points;
  * `crash_copy`: clone the durability directory and truncate/corrupt
    the WAL at an arbitrary byte offset, dropping any snapshot whose
    watermark exceeds the surviving log (a real crash cannot produce
    one — `Durability.snapshot` syncs the log before serializing);
  * the sequential oracle: a fresh *non-durable* engine fed the exact
    durable op prefix, cached per prefix length so a sweep of crash
    points at the same boundary prices one oracle build.

The correctness claim under test: `restore()` after any crash is
answer-exact — bitwise-equal lookups and ranges — vs the oracle for the
durable prefix, on both drivers and both backends, regardless of where
inside a record (or between records) the crash landed.
"""
import os
import shutil

import numpy as np
import pytest

from repro.core.params import SLSMParams, TuningPolicy
from repro.engine import wal as WAL
from repro.engine.engine import SLSM
from repro.engine.sharded import ShardedSLSM

KEY_SPACE = 4000

DRIVERS = ("single", "sharded")
BACKENDS = ("jnp", "pallas")


def small_params(backend: str = "jnp", adaptive: bool = False) -> SLSMParams:
    """Tiny geometry (R=2, Rn=32, D=2) so a short stream exercises
    seals, flushes, spills, and compactions; `adaptive` switches on the
    tuner with a small decision interval so retunes happen in-stream."""
    tuning = (TuningPolicy(mode="adaptive", interval=64)
              if adaptive else TuningPolicy())
    return SLSMParams(R=2, Rn=32, eps=1e-2, D=2, m=1.0, mu=16, max_levels=3,
                      max_range=2048, merge_budget=1, backend=backend,
                      tuning=tuning)


def make_engine(driver: str, p: SLSMParams, durability=None):
    """One constructor for the driver axis of the test matrix."""
    if driver == "sharded":
        return ShardedSLSM(p, n_shards=2, durability=durability)
    return SLSM(p, durability=durability)


def write_stream(n_ops: int = 12, op_size: int = 48, seed: int = 0):
    """Deterministic mixed op stream: every 4th op deletes a slice of
    the keys the previous ops wrote (so tombstones ride the WAL), the
    rest insert with overwrites (key space is small enough to collide).
    One list entry == one driver call == one WAL WRITE record."""
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        keys = rng.integers(0, KEY_SPACE, op_size).astype(np.int32)
        if i % 4 == 3:
            ops.append(("delete", keys[:op_size // 3], None))
        else:
            vals = rng.integers(0, 1 << 20, op_size).astype(np.int32)
            ops.append(("insert", keys, vals))
    return ops


def apply_ops(drv, ops, upto=None):
    """Feed `ops[:upto]` (None = all) through the classic driver calls."""
    for kind, keys, vals in (ops if upto is None else ops[:upto]):
        if kind == "insert":
            drv.insert(keys, vals)
        else:
            drv.delete(keys)


def probe_answers(drv, key_space: int = KEY_SPACE):
    """The oracle-comparison read set: a full-keyspace-stride batched
    lookup plus a sweep of range windows (whole space, small, straddling
    levels). Returns plain numpy so comparisons are bitwise."""
    probe = np.arange(0, key_space, 3, dtype=np.int32)
    v, f = drv.lookup_many(probe)
    rs = []
    for lo, hi in ((0, key_space), (123, 456), (1000, 3500)):
        k, vv = drv.range(lo, hi)
        rs.append((np.asarray(k), np.asarray(vv)))
    return np.asarray(v), np.asarray(f), rs


def assert_same_answers(got, want, strict_vals: bool = True):
    """Bitwise answer equality. `strict_vals=False` compares lookup
    values only on found lanes (cross-driver-class comparisons: the
    not-found lanes' padding is an implementation detail)."""
    gv, gf, gr = got
    wv, wf, wr = want
    np.testing.assert_array_equal(gf, wf)
    if strict_vals:
        np.testing.assert_array_equal(gv, wv)
    else:
        np.testing.assert_array_equal(gv[gf], wv[wf])
    assert len(gr) == len(wr)
    for (gk, gvv), (wk, wvv) in zip(gr, wr):
        np.testing.assert_array_equal(gk, wk)
        np.testing.assert_array_equal(gvv, wvv)


def crash_copy(durdir, dst, cut=None, corrupt=None):
    """Simulate a crash: clone the durability dir, then truncate the
    WAL at byte `cut` and/or XOR-flip the byte at offset `corrupt`.
    Snapshots whose watermark exceeds the surviving log's last seqno
    are dropped — a real crash cannot produce one, since snapshot()
    group-commits the WAL before serializing. Returns `dst`."""
    shutil.copytree(durdir, dst)
    wal_path = os.path.join(dst, "wal.log")
    if cut is not None:
        with open(wal_path, "r+b") as f:
            f.truncate(cut)
    if corrupt is not None:
        with open(wal_path, "r+b") as f:
            f.seek(corrupt)
            b = f.read(1)
            f.seek(corrupt)
            f.write(bytes([b[0] ^ 0xFF]))
    records, _ = WAL.read_wal(wal_path)
    last = records[-1].seqno if records else -1
    for num, spath in WAL.list_snapshots(dst):
        if num > last:
            shutil.rmtree(spath)
    return dst


def durable_write_ops(wal_path) -> int:
    """How many write ops the well-formed WAL prefix holds — the oracle
    prefix length j (one WRITE record per op, by construction)."""
    return sum(1 for r in WAL.read_wal(wal_path)[0]
               if r.kind in WAL.WRITE_KINDS)


class CrashHarness:
    """Caches one reference run and its oracles per test-matrix cell.

    A cell is (driver, backend, adaptive): `reference()` builds the
    durable run once (returning the durability dir, the op stream, the
    record byte-extent map, and per-op maintenance-counter deltas so
    tests can find the mid-seal/mid-spill ops); `oracle(j)` builds —
    and caches — the sequential-oracle answers for the j-op prefix;
    `restore_at()` crash-copies, restores, and returns the restored
    driver plus its durable prefix length."""

    def __init__(self, tmp_factory):
        self.tmp = tmp_factory
        self._refs = {}
        self._oracles = {}
        self._n = 0

    def _dir(self, tag: str) -> str:
        self._n += 1
        return str(self.tmp.mktemp(f"{tag}-{self._n}"))

    def reference(self, driver: str, backend: str, adaptive: bool = False,
                  n_ops: int = 12, snapshot_at=None):
        """The durable reference run for one matrix cell (cached)."""
        key = (driver, backend, adaptive, n_ops, snapshot_at)
        if key in self._refs:
            return self._refs[key]
        p = small_params(backend, adaptive)
        durdir = self._dir(f"ref-{driver}-{backend}")
        dur = WAL.Durability(durdir, fsync=False,
                             snapshot_every_bytes=1 << 30)
        drv = make_engine(driver, p, durability=dur)
        ops = write_stream(n_ops=n_ops)
        deltas = []
        for i, (kind, keys, vals) in enumerate(ops):
            before = dict(drv.stats)
            if kind == "insert":
                drv.insert(keys, vals)
            else:
                drv.delete(keys)
            deltas.append({k: drv.stats[k] - before.get(k, 0)
                           for k in ("seals", "flushes", "spills",
                                     "compactions", "retunes")})
            if snapshot_at is not None and i == snapshot_at:
                drv.snapshot()
        dur.close()
        ref = {"dir": durdir, "ops": ops, "params": p,
               "offsets": WAL.record_offsets(os.path.join(durdir,
                                                          "wal.log")),
               "deltas": deltas, "answers": probe_answers(drv)}
        self._refs[key] = ref
        return ref

    def oracle(self, driver: str, backend: str, adaptive: bool, ops, j: int):
        """Answers of a fresh non-durable engine fed ops[:j] (cached)."""
        key = (driver, backend, adaptive, len(ops), j)
        if key not in self._oracles:
            drv = make_engine(driver, small_params(backend, adaptive))
            apply_ops(drv, ops, upto=j)
            self._oracles[key] = probe_answers(drv)
        return self._oracles[key]

    def restore_at(self, ref, driver: str, cut=None, corrupt=None):
        """Crash-copy the reference dir at (`cut`, `corrupt`) and
        restore; returns (restored driver, durable write-op count)."""
        dst = self._dir("crash")
        os.rmdir(dst)              # copytree wants to create it
        crash_copy(ref["dir"], dst, cut=cut, corrupt=corrupt)
        j = durable_write_ops(os.path.join(dst, "wal.log"))
        cls = ShardedSLSM if driver == "sharded" else SLSM
        return cls.restore(dst), j
