"""Crash-point injection: restore() is oracle-exact at every boundary.

The acceptance matrix (ISSUE 7): byte-level torn tails, chunk (record)
boundaries, mid-seal, mid-spill, and mid-RETUNE crash points, on both
drivers x both backends, plus snapshot-watermark crashes and the
serving Governor's idle-gap snapshot trigger. Every test reduces to the
same oracle claim: whatever byte the WAL dies at, `restore()` answers
exactly like a fresh engine fed the durable op prefix.

The pallas cells run the same boundaries over a shorter stream — the
kernels execute in interpret mode on CPU, so every dispatch is orders
of magnitude slower than compiled jnp.
"""
import numpy as np
import pytest

from repro.engine import wal as WAL

from harness import (BACKENDS, DRIVERS, assert_same_answers,
                     make_engine, small_params, write_stream)

_HDR = WAL._HEADER.size


def _cells(full=True):
    out = []
    for d in DRIVERS:
        for b in BACKENDS:
            if full or b == "jnp":
                out.append((d, b))
    return out


def _n_ops(backend: str) -> int:
    return 12 if backend == "jnp" else 6


@pytest.mark.parametrize("driver,backend", _cells())
def test_torn_tail_byte_level(harness, driver, backend):
    """Cuts at arbitrary byte offsets inside the final records: the torn
    record is dropped as a unit and restore lands exactly on the last
    complete op."""
    from harness import probe_answers
    ref = harness.reference(driver, backend, n_ops=_n_ops(backend))
    offsets = ref["offsets"]
    writes = [(rec, s, e) for rec, s, e in offsets
              if rec.kind in WAL.WRITE_KINDS]
    targets = writes[-2:] if backend == "jnp" else writes[-1:]
    for rec, start, end in targets:
        for cut in (start + 1, start + _HDR, start + _HDR + 5, end - 1):
            drv, j = harness.restore_at(ref, driver, cut=cut)
            want = harness.oracle(driver, backend, False, ref["ops"], j)
            assert_same_answers(probe_answers(drv), want)
            # the torn record itself is not in the durable prefix
            assert j < sum(1 for r, _, _ in offsets
                           if r.kind in WAL.WRITE_KINDS and r.seqno <= rec.seqno)


@pytest.mark.parametrize("driver,backend", _cells())
def test_chunk_boundary_cuts(harness, driver, backend):
    """Cuts exactly at record boundaries: the durable prefix is every
    op up to the cut, nothing more, nothing less."""
    from harness import probe_answers
    ref = harness.reference(driver, backend, n_ops=_n_ops(backend))
    writes = [(rec, s, e) for rec, s, e in ref["offsets"]
              if rec.kind in WAL.WRITE_KINDS]
    picks = ([0, len(writes) // 2, len(writes) - 1] if backend == "jnp"
             else [len(writes) - 1])
    seen_j = set()
    for i in picks:
        _, _, end = writes[i]
        drv, j = harness.restore_at(ref, driver, cut=end)
        assert j == i + 1          # exactly the ops before the boundary
        want = harness.oracle(driver, backend, False, ref["ops"], j)
        assert_same_answers(probe_answers(drv), want)
        seen_j.add(j)
    assert len(seen_j) == len(picks)


@pytest.mark.parametrize("driver,backend", _cells())
def test_mid_seal_and_mid_spill(harness, driver, backend):
    """Crashes inside the records of ops that triggered seals and spills
    (the per-op maintenance deltas of the reference run say which):
    maintenance progress is never replay-relevant — restore still lands
    answer-exact on the op boundary."""
    from harness import probe_answers
    # the sharded cells route ~half the stream to each shard, so the
    # short pallas stream never fills a shard's memory tier — they need
    # the full 12 ops to provoke a spill
    n_ops = 12 if driver == "sharded" else _n_ops(backend)
    ref = harness.reference(driver, backend, n_ops=n_ops)
    writes = [(rec, s, e) for rec, s, e in ref["offsets"]
              if rec.kind in WAL.WRITE_KINDS]
    seal_ops = [i for i, d in enumerate(ref["deltas"]) if d["seals"]]
    spill_ops = [i for i, d in enumerate(ref["deltas"]) if d["spills"]]
    assert seal_ops, "stream too small: no op sealed"
    assert spill_ops, "stream too small: no op spilled"
    targets = ([seal_ops[0], seal_ops[-1], spill_ops[0], spill_ops[-1]]
               if backend == "jnp" else [seal_ops[-1], spill_ops[-1]])
    for i in sorted(set(targets)):
        _, start, end = writes[i]
        for cut in (start + _HDR + 3, end):
            drv, j = harness.restore_at(ref, driver, cut=cut)
            assert j == (i if cut < end else i + 1)
            want = harness.oracle(driver, backend, False, ref["ops"], j)
            assert_same_answers(probe_answers(drv), want)


@pytest.mark.parametrize("driver", DRIVERS)
def test_mid_retune(harness, driver):
    """A crash inside (or right after) a logged RETUNE record: the
    switch is answer-invariant, so restore is oracle-exact whether the
    record survived or was torn away."""
    from harness import apply_ops, probe_answers
    p = small_params("jnp", adaptive=True)
    durdir = harness._dir(f"retune-{driver}")
    dur = WAL.Durability(durdir, fsync=False, snapshot_every_bytes=1 << 30)
    drv = make_engine(driver, p, durability=dur)
    ops = write_stream(n_ops=6)
    apply_ops(drv, ops[:4])
    # read-heavy phase rolls the tuner toward the read allocation;
    # decisions bind at the next write boundary (scheduler invariant)
    probe = np.arange(0, 4000, 2, dtype=np.int32)
    for _ in range(12):
        drv.lookup_many(probe)
    apply_ops(drv, ops[4:])
    dur.close()
    assert drv.stats["retunes"] >= 1, "stream failed to provoke a retune"
    wal_path = durdir + "/wal.log"
    offsets = WAL.record_offsets(wal_path)
    retunes = [(r, s, e) for r, s, e in offsets
               if r.kind == WAL.REC_RETUNE]
    assert retunes, "no RETUNE record reached the WAL"
    rec, start, end = retunes[-1]
    ref = {"dir": durdir, "ops": ops, "offsets": offsets}
    for cut in (start + 1, start + _HDR, end):
        dst_drv, j = harness.restore_at(ref, driver, cut=cut)
        want_drv = make_engine(driver, p)
        apply_ops(want_drv, ops, upto=j)
        assert_same_answers(probe_answers(dst_drv),
                            probe_answers(want_drv))


@pytest.mark.parametrize("driver", DRIVERS)
def test_crash_around_snapshot_watermark(harness, driver):
    """Cuts before, at, and after a mid-stream snapshot's watermark:
    after it, restore replays only the tail; before it, the
    from-the-future snapshot is dropped and recovery replays from
    genesis — both oracle-exact."""
    from harness import probe_answers
    ref = harness.reference(driver, "jnp", n_ops=12, snapshot_at=6)
    snaps = WAL.list_snapshots(ref["dir"])
    assert len(snaps) == 1
    watermark = snaps[0][0]
    writes = [(rec, s, e) for rec, s, e in ref["offsets"]
              if rec.kind in WAL.WRITE_KINDS]
    before = [e for rec, s, e in writes if rec.seqno < watermark][-2]
    after = [e for rec, s, e in writes if rec.seqno > watermark]
    for cut in (before, after[0], after[-1], after[-1] - 3):
        drv, j = harness.restore_at(ref, driver, cut=cut)
        want = harness.oracle(driver, "jnp", False, ref["ops"], j)
        assert_same_answers(probe_answers(drv), want)
    # full (uncut) restore must also use the snapshot: tail-only replay
    cls = type(make_engine(driver, small_params()))
    full = cls.restore(ref["dir"])
    total_writes = len(writes)
    assert full.stats["replayed_records"] < total_writes
    assert_same_answers(probe_answers(full), ref["answers"])


def test_governor_idle_snapshot_and_serving_restore(harness, tmp_path):
    """End-to-end through repro.serve: a durable served engine
    snapshots in an idle pump once the WAL passes its threshold
    (Governor.idle), the durability block shows up in stats(), and a
    restore of the serving directory answers exactly like the live
    server's engine."""
    from repro.serve.server import Server

    from harness import probe_answers
    p = small_params("jnp")
    dur = WAL.Durability(str(tmp_path), fsync=False,
                         snapshot_every_bytes=2048)
    drv = make_engine("single", p, durability=dur)
    srv = Server(drv)
    rng = np.random.default_rng(3)
    for i in range(6):
        keys = rng.integers(0, 4000, 64).astype(np.int32)
        vals = rng.integers(0, 1 << 20, 64).astype(np.int32)
        srv.submit(f"c{i % 2}", "insert", keys, vals)
        srv.pump(force=True)    # one served (and WAL-synced) window each
    srv.pump()                  # nothing pending: the governor's idle gap
    st = srv.stats()
    assert st["durability"] is not None
    assert st["durability"]["wal_records"] >= 6
    assert srv.governor.snapshots_run >= 1
    assert st["governor"]["snapshots"] == srv.governor.snapshots_run
    dur.close()
    restored = type(drv).restore(str(tmp_path))
    assert_same_answers(probe_answers(restored), probe_answers(drv))
    # the restore stall is first-class telemetry
    assert restored.stats["restore_us"] > 0
    srv2 = Server(restored)
    assert srv2.stats()["engine"]["restore_us"] > 0
