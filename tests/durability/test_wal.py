"""Unit coverage of `repro.engine.wal`: record framing, torn-tail
truncation, the snapshot codec, the Durability manager's contracts, the
driver `restore` edge cases, and the `repro.checkpoint` facade that now
rides the same serialization path (ISSUE 7 satellite: one path, no
drift)."""
import json
import os
import struct
from pathlib import Path

import numpy as np
import pytest

from repro.core.params import SLSMParams, TuningPolicy
from repro.engine import wal as WAL
from repro.engine.engine import SLSM

from harness import (apply_ops, assert_same_answers, make_engine,
                     probe_answers, small_params, write_stream)


# --------------------------------------------------------------------------
# record framing
# --------------------------------------------------------------------------

def test_write_codec_roundtrip():
    k = np.array([5, -3, 7], np.int32)
    v = np.array([50, -30, 70], np.int32)
    w = np.array([1, -1, 1], np.int8)
    k2, v2, w2 = WAL.decode_write(WAL.encode_write(k, v, w))
    np.testing.assert_array_equal(k, k2)
    np.testing.assert_array_equal(v, v2)
    np.testing.assert_array_equal(w, w2)
    # empty chunks frame fine too (drivers skip logging them, but the
    # codec itself is total)
    k3, v3, w3 = WAL.decode_write(WAL.encode_write([], [], []))
    assert k3.size == 0 and v3.size == 0 and w3.size == 0


def test_write_codec_shape_mismatch():
    with pytest.raises(ValueError, match="must match"):
        WAL.encode_write([1, 2], [1], [1, 1])
    with pytest.raises(ValueError, match="must match"):
        WAL.encode_write([1, 2], [1, 2], [1])


def test_legacy_write_record_decodes_as_weighted():
    """A format-1 REC_WRITE payload (keys+vals, TOMBSTONE value means
    delete) decodes to weighted form: wt -1 + payload 0 on the
    TOMBSTONE lanes, wt +1 elsewhere — pre-§13 logs replay exactly."""
    from repro.core.params import TOMBSTONE
    k = np.array([5, 9, 11], np.int32)
    v = np.array([50, TOMBSTONE, 110], np.int32)
    payload = struct.pack("<I", 3) + k.tobytes() + v.tobytes()
    k2, v2, w2 = WAL.decode_write(payload, WAL.REC_WRITE)
    np.testing.assert_array_equal(k2, k)
    np.testing.assert_array_equal(v2, [50, 0, 110])
    np.testing.assert_array_equal(w2, [1, -1, 1])


def test_read_wal_missing_and_bad_magic(tmp_path):
    assert WAL.read_wal(tmp_path / "nope.log") == ([], 0)
    bad = tmp_path / "bad.log"
    bad.write_bytes(b"NOTAWAL!" + WAL.encode_record(0, WAL.REC_RETUNE, b"x"))
    assert WAL.read_wal(bad) == ([], 0)


def _write_raw(path, recs):
    path.write_bytes(WAL.MAGIC + b"".join(recs))


def test_read_wal_stops_at_crc_break(tmp_path):
    p = tmp_path / "wal.log"
    good = [WAL.encode_record(i, WAL.REC_RETUNE, f"r{i}".encode())
            for i in range(3)]
    blob = WAL.MAGIC + b"".join(good)
    # flip one payload byte inside the SECOND record
    off = len(WAL.MAGIC) + len(good[0]) + WAL._HEADER.size
    blob = blob[:off] + bytes([blob[off] ^ 0xFF]) + blob[off + 1:]
    p.write_bytes(blob)
    records, good_bytes = WAL.read_wal(p)
    assert [r.seqno for r in records] == [0]
    assert good_bytes == len(WAL.MAGIC) + len(good[0])


def test_read_wal_stops_at_seqno_gap(tmp_path):
    p = tmp_path / "wal.log"
    _write_raw(p, [WAL.encode_record(0, WAL.REC_RETUNE, b"a"),
                   WAL.encode_record(1, WAL.REC_RETUNE, b"b"),
                   WAL.encode_record(3, WAL.REC_RETUNE, b"gap")])
    records, _ = WAL.read_wal(p)
    assert [r.seqno for r in records] == [0, 1]


def test_read_wal_drops_short_tail(tmp_path):
    p = tmp_path / "wal.log"
    rec = WAL.encode_record(0, WAL.REC_WRITE2,
                            WAL.encode_write([1], [2], [1]))
    torn = WAL.encode_record(1, WAL.REC_WRITE2,
                             WAL.encode_write([3], [4], [1]))
    for cut in (1, WAL._HEADER.size, len(torn) - 1):
        _write_raw(p, [rec, torn[:cut]])
        records, good = WAL.read_wal(p)
        assert [r.seqno for r in records] == [0]
        assert good == len(WAL.MAGIC) + len(rec)


def test_read_wal_rejects_implausible_length(tmp_path):
    p = tmp_path / "wal.log"
    head = WAL._HEADER.pack(0, WAL._MAX_PAYLOAD + 1, 0, WAL.REC_WRITE2, 0)
    _write_raw(p, [head + b"x" * 64])
    assert WAL.read_wal(p)[0] == []


def test_read_wal_rejects_stale_prior_epoch_tail(tmp_path):
    """ISSUE 9 regression: promote() reuses the WAL file in place. A
    crash cut that lands *exactly on a record boundary* can expose
    stale frames from the pre-failover lineage past it — CRC-valid and,
    when the new lineage wrote fewer records, seqno-consecutive too.
    The prefix rule must reject them anyway: they carry an older
    epoch."""
    p = tmp_path / "wal.log"
    old = [WAL.encode_record(s, WAL.REC_RETUNE, b"old", epoch=0)
           for s in range(10)]
    new = [WAL.encode_record(s, WAL.REC_RETUNE, b"new", epoch=1)
           for s in (6, 7)]
    # post-crash file: live prefix [0..5 @e0][6..7 @e1], then stale
    # pre-promote frames 8..9 @e0 record-aligned past the cut
    stale = old[8:]
    _write_raw(p, old[:6] + new + stale)
    records, good = WAL.read_wal(p)
    assert [r.seqno for r in records] == list(range(8))
    assert [r.epoch for r in records] == [0] * 6 + [1, 1]
    # the stale frames are individually well-formed and seqno-
    # consecutive — the epoch check is the only thing rejecting them
    assert WAL.check_frame(stale[0]).seqno == 8
    assert good == os.path.getsize(p) - sum(len(f) for f in stale)
    # and a resuming writer truncates them away, continuing at epoch 1
    w = WAL.WalWriter(p)
    assert (w.next_seqno, w.epoch) == (8, 1)
    w.close()
    assert os.path.getsize(p) == good


def test_check_frame_total():
    frame = WAL.encode_record(7, WAL.REC_RETUNE, b"x", epoch=3)
    rec = WAL.check_frame(frame)
    assert (rec.seqno, rec.kind, rec.payload, rec.epoch) == (
        7, WAL.REC_RETUNE, b"x", 3)
    assert WAL.check_frame(frame[:-1]) is None          # truncated
    assert WAL.check_frame(frame + b"y") is None        # trailing junk
    bad = bytearray(frame)
    bad[WAL._HEADER.size] ^= 0xFF
    assert WAL.check_frame(bytes(bad)) is None          # payload flip
    assert WAL.check_frame(b"") is None


# --------------------------------------------------------------------------
# WalWriter
# --------------------------------------------------------------------------

def test_writer_resumes_and_truncates_torn_tail(tmp_path):
    p = tmp_path / "wal.log"
    w = WAL.WalWriter(p)
    assert w.append(WAL.REC_RETUNE, b"a") == 0
    assert w.append(WAL.REC_RETUNE, b"b") == 1
    w.sync(fsync=False)
    w.close()
    # tear the tail mid-record, then reopen: the torn record is
    # physically truncated away and seqnos resume after the survivor
    size = p.stat().st_size
    with open(p, "r+b") as f:
        f.truncate(size - 3)
    w2 = WAL.WalWriter(p)
    assert w2.last_seqno == 0
    assert p.stat().st_size == size - 3 - (WAL._HEADER.size + 1 - 3)
    assert w2.append(WAL.REC_RETUNE, b"c") == 1
    w2.close()
    records, _ = WAL.read_wal(p)
    assert [(r.seqno, r.payload) for r in records] == [(0, b"a"), (1, b"c")]


def test_writer_unreadable_log_starts_over(tmp_path):
    p = tmp_path / "wal.log"
    p.write_bytes(b"garbage that is not a WAL at all")
    w = WAL.WalWriter(p)
    assert w.next_seqno == 0
    w.append(WAL.REC_RETUNE, b"x")
    w.close()
    records, _ = WAL.read_wal(p)
    assert [r.payload for r in records] == [b"x"]


def test_writer_min_next_seqno(tmp_path):
    w = WAL.WalWriter(tmp_path / "wal.log", min_next_seqno=17)
    assert w.append(WAL.REC_RETUNE, b"x") == 17


def test_writer_append_buffers_until_sync(tmp_path):
    p = tmp_path / "wal.log"
    w = WAL.WalWriter(p)
    w.append(WAL.REC_RETUNE, b"x")
    assert WAL.read_wal(p)[0] == []        # not on disk yet
    w.sync(fsync=False)
    assert len(WAL.read_wal(p)[0]) == 1
    assert w.syncs == 1
    w.sync(fsync=False)                    # empty batch: no-op
    assert w.syncs == 1
    w.close()


def test_writer_bump_epoch_stamps_and_resumes(tmp_path):
    p = tmp_path / "wal.log"
    w = WAL.WalWriter(p)
    w.append(WAL.REC_RETUNE, b"a")
    assert w.bump_epoch() == 1
    w.append(WAL.REC_RETUNE, b"b")
    w.close()
    records, _ = WAL.read_wal(p)
    assert [(r.seqno, r.epoch) for r in records] == [(0, 0), (1, 1)]
    w2 = WAL.WalWriter(p)                  # reopen resumes at epoch 1
    assert w2.epoch == 1
    w2.append(WAL.REC_RETUNE, b"c")
    w2.close()
    assert WAL.read_wal(p)[0][-1].epoch == 1


def test_append_frame_verbatim_and_validated(tmp_path):
    leader = WAL.WalWriter(tmp_path / "leader.log")
    for i in range(3):
        leader.append(WAL.REC_RETUNE, f"r{i}".encode())
    leader.close()
    frames = [WAL.encode_record(r.seqno, r.kind, r.payload, r.epoch)
              for r in WAL.read_wal(leader.path)[0]]
    f = WAL.WalWriter(tmp_path / "follower.log")
    with pytest.raises(ValueError, match="seqno"):
        f.append_frame(frames[1])          # gap: 1 before 0
    f.append_frame(frames[0])
    bad = bytearray(frames[1])
    bad[-1] ^= 0xFF
    with pytest.raises(ValueError, match="malformed"):
        f.append_frame(bytes(bad))         # CRC flip rejected
    f.append_frame(frames[1])              # ...without poisoning the log
    f.append_frame(frames[2])
    with pytest.raises(ValueError, match="epoch regressed"):
        f.bump_epoch()
        f.append_frame(WAL.encode_record(3, WAL.REC_RETUNE, b"x", epoch=0))
    f.close()
    # follower log is a bitwise copy of the leader's stream
    assert (tmp_path / "follower.log").read_bytes() == \
        (tmp_path / "leader.log").read_bytes()


def test_wal_tailer_yields_each_frame_once(tmp_path):
    p = tmp_path / "wal.log"
    w = WAL.WalWriter(p)
    t = WAL.WalTailer(p)
    assert t.poll() == []
    w.append(WAL.REC_RETUNE, b"a")
    assert t.poll() == []                  # buffered, not durable
    w.sync(fsync=False)
    got = t.poll()
    assert [(r.seqno, r.payload) for r, _ in got] == [(0, b"a")]
    assert t.poll() == []                  # exactly once
    w.append(WAL.REC_RETUNE, b"b")
    w.append(WAL.REC_RETUNE, b"c")
    w.sync(fsync=False)
    assert [r.seqno for r, _ in t.poll(max_records=1)] == [1]
    assert [r.seqno for r, _ in t.poll()] == [2]
    # a torn tail stays pending until the writer completes it
    frame = WAL.encode_record(3, WAL.REC_RETUNE, b"d", epoch=0)
    with open(p, "ab") as fh:
        fh.write(frame[:7])
    assert t.poll() == []
    with open(p, "ab") as fh:
        fh.write(frame[7:])
    assert [r.seqno for r, _ in t.poll()] == [3]
    # shipped frames are the file's bytes verbatim
    t2 = WAL.WalTailer(p)
    assert b"".join(f for _, f in t2.poll()) == p.read_bytes()[len(WAL.MAGIC):]
    w.close()


def test_wal_tailer_rewind_retransmits(tmp_path):
    p = tmp_path / "wal.log"
    w = WAL.WalWriter(p)
    offs = [len(WAL.MAGIC)]
    for i in range(3):
        w.append(WAL.REC_RETUNE, f"r{i}".encode())
        w.sync(fsync=False)
        offs.append(w.size)
    t = WAL.WalTailer(p)
    assert [r.seqno for r, _ in t.poll()] == [0, 1, 2]
    t.rewind(offs[1], 1)
    assert [r.seqno for r, _ in t.poll()] == [1, 2]
    w.close()


def test_wal_tailer_follows_sealing_that_leaves_active_empty(tmp_path):
    """Tiny segments can seal on *every* sync, so the active file is
    empty whenever the tailer looks: a cursor parked at the head of the
    empty active must still notice that the frames it awaits were
    sealed into the chain underneath it and hop there — a cursor that
    only watches the active file stalls forever (the replication
    leader would ship nothing despite a growing durable stream)."""
    dur = WAL.Durability(tmp_path, fsync=False, segment_bytes=1)
    dur.log_retune("r0")
    dur.sync()                             # seals immediately: active empty
    t = WAL.WalTailer(dur.wal_path)
    assert [r.seqno for r, _ in t.poll()] == [0]
    assert t.poll() == []                  # parked at the empty active head
    for i in range(1, 4):                  # every append seals a segment
        dur.log_retune(f"r{i}")
        dur.sync()
    assert (dur.wal_path.read_bytes() == WAL.MAGIC
            and dur.stats()["wal_segments"] >= 4)
    assert [r.seqno for r, _ in t.poll()] == [1, 2, 3], \
        "frames sealed under a parked cursor must still ship"
    assert t.poll() == []                  # exactly once, then parked again
    dur.log_retune("r4")
    dur.sync()
    assert [r.seqno for r, _ in t.poll()] == [4]
    dur.close()


# --------------------------------------------------------------------------
# snapshot codec
# --------------------------------------------------------------------------

def _leaves(rng):
    import ml_dtypes
    return [np.asarray(rng.normal(size=(8, 4)), np.float32),
            np.asarray(rng.normal(size=(16,)), ml_dtypes.bfloat16),
            np.arange(6, dtype=np.int32)]


def test_snapshot_roundtrip_with_bfloat16(tmp_path, rng):
    leaves = _leaves(rng)
    path = WAL.write_snapshot(tmp_path, 3, leaves, {"seqno": 3})
    assert path.name == "snap_3"
    got, meta = WAL.read_snapshot(path)
    assert meta["seqno"] == 3
    for a, b in zip(leaves, got):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a).view(np.uint8).ravel(),
                                      np.asarray(b).view(np.uint8).ravel())


def test_snapshot_corruption_detected(tmp_path, rng):
    path = WAL.write_snapshot(tmp_path, 1, _leaves(rng), {})
    leaf = path / "leaf_0.npy"
    blob = bytearray(leaf.read_bytes())
    blob[-1] ^= 0xFF
    leaf.write_bytes(bytes(blob))
    with pytest.raises(WAL.SnapshotError, match="corruption"):
        WAL.read_snapshot(path)


def test_list_snapshots_numeric_order_and_keep_last(tmp_path, rng):
    for n in (2, 10, 1):
        WAL.write_snapshot(tmp_path, n, _leaves(rng), {})
    assert [n for n, _ in WAL.list_snapshots(tmp_path)] == [1, 2, 10]
    WAL.write_snapshot(tmp_path, 11, _leaves(rng), {}, keep_last=2)
    assert [n for n, _ in WAL.list_snapshots(tmp_path)] == [10, 11]


def test_gc_tmp_snapshots(tmp_path):
    orphan = tmp_path / "snap_5.tmp-1234"
    orphan.mkdir()
    (orphan / "leaf_0.npy").write_bytes(b"partial")
    WAL.gc_tmp_snapshots(tmp_path)
    assert not orphan.exists()
    assert WAL.list_snapshots(tmp_path) == []


def test_load_latest_falls_back_past_corruption(tmp_path, rng, capsys):
    leaves = _leaves(rng)
    WAL.write_snapshot(tmp_path, 1, leaves, {"tag": "old"})
    bad = WAL.write_snapshot(tmp_path, 2, leaves, {"tag": "new"})
    (bad / "leaf_1.npy").write_bytes(b"smashed")
    num, got, meta = WAL.load_latest_snapshot(tmp_path)
    assert num == 1 and meta["tag"] == "old"
    assert len(got) == len(leaves)
    assert "skipping bad snapshot snap_2" in capsys.readouterr().err


# --------------------------------------------------------------------------
# params fingerprint
# --------------------------------------------------------------------------

def test_params_dict_roundtrip():
    p = SLSMParams(R=3, Rn=64, eps=1e-2, D=2, m=1.0, mu=16, max_levels=2,
                   eps_per_level=(1e-2, 5e-3),
                   tuning=TuningPolicy(mode="adaptive", interval=32))
    q = WAL.params_from_dict(json.loads(json.dumps(WAL.params_to_dict(p))))
    assert q == p


# --------------------------------------------------------------------------
# Durability manager
# --------------------------------------------------------------------------

def test_ensure_header_rejects_foreign_engine(tmp_path):
    d1 = WAL.Durability(tmp_path, fsync=False)
    d1.ensure_header({"driver": "slsm", "params": {"R": 2}})
    d1.close()
    d2 = WAL.Durability(tmp_path, fsync=False)
    d2.ensure_header({"driver": "slsm", "params": {"R": 2}})  # same: fine
    d2.close()
    d3 = WAL.Durability(tmp_path, fsync=False)
    with pytest.raises(ValueError, match="different engine"):
        d3.ensure_header({"driver": "sharded", "params": {"R": 2}})
    d3.close()


def test_should_snapshot_threshold(tmp_path):
    dur = WAL.Durability(tmp_path, fsync=False, snapshot_every_bytes=256)
    assert not dur.should_snapshot()       # no writer yet
    while not dur.should_snapshot():
        dur.log_write(np.arange(8, dtype=np.int32),
                      np.arange(8, dtype=np.int32),
                      np.ones(8, dtype=np.int8))
        dur.sync()
    st = dur.stats()
    assert st["bytes_since_snapshot"] >= 256
    assert st["wal_records"] == st["wal_syncs"] > 0
    assert set(st) == {"wal_bytes", "wal_active_bytes", "wal_segments",
                       "wal_rolls", "wal_pruned_bytes",
                       "wal_pruned_segments", "wal_records", "wal_syncs",
                       "replica", "snapshots", "snapshot_ms_last",
                       "bytes_since_snapshot"}
    dur.close()


def test_as_durability_coercions(tmp_path):
    assert WAL.as_durability(None) is None
    dur = WAL.Durability(tmp_path)
    assert WAL.as_durability(dur) is dur
    made = WAL.as_durability(str(tmp_path / "sub"))
    assert isinstance(made, WAL.Durability)
    assert made.dir == Path(tmp_path / "sub")


# --------------------------------------------------------------------------
# driver restore edge cases
# --------------------------------------------------------------------------

def test_restore_without_snapshot_replays_from_genesis(tmp_path):
    p = small_params()
    dur = WAL.Durability(tmp_path, fsync=False,
                         snapshot_every_bytes=1 << 30)
    drv = make_engine("single", p, durability=dur)
    ops = write_stream(n_ops=6)
    apply_ops(drv, ops)
    dur.close()
    assert WAL.list_snapshots(tmp_path) == []
    got = SLSM.restore(str(tmp_path))
    # params resolved from the WAL's META fingerprint, not re-supplied
    assert got.p == p
    assert got.stats["replayed_records"] == 6
    assert got.stats["restore_us"] > 0
    assert_same_answers(probe_answers(got), probe_answers(drv))


def test_restore_empty_dir_is_fresh_engine(tmp_path):
    with pytest.raises(ValueError, match="nothing to restore"):
        SLSM.restore(str(tmp_path / "a"))  # no fingerprint, no params
    drv = SLSM.restore(str(tmp_path), params=small_params())
    assert drv.stats["replayed_records"] == 0
    vals, found = drv.lookup_many(np.array([1, 2, 3], np.int32))
    assert not np.asarray(found).any()


def test_restore_then_continue_writing(tmp_path):
    """The restored engine's Durability keeps appending where the
    crashed log stopped — seqnos stay strictly consecutive."""
    p = small_params()
    dur = WAL.Durability(tmp_path, fsync=False)
    drv = make_engine("single", p, durability=dur)
    ops = write_stream(n_ops=6)
    apply_ops(drv, ops[:4])
    dur.close()
    got = SLSM.restore(str(tmp_path))
    apply_ops(got, ops[4:])
    got.durability.close()
    records, _ = WAL.read_wal(Path(tmp_path) / "wal.log")
    seqs = [r.seqno for r in records]
    assert seqs == list(range(len(seqs)))
    assert sum(1 for r in records if r.kind in WAL.WRITE_KINDS) == 6
    want = make_engine("single", p)
    apply_ops(want, ops)
    assert_same_answers(probe_answers(got), probe_answers(want))


# --------------------------------------------------------------------------
# repro.checkpoint facade (folded from the retired test_checkpoint.py)
# --------------------------------------------------------------------------

def _tree(rng):
    import jax.numpy as jnp
    return {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)}


def test_checkpoint_roundtrip(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    restored, step = mgr.restore(tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(restored["w"]))
    np.testing.assert_array_equal(
        np.asarray(tree["b"]).view(np.uint16),
        np.asarray(restored["b"]).view(np.uint16))


def test_checkpoint_keep_last_and_latest(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for step in range(4):
        mgr.save(step, tree)
    assert mgr.latest_step() == 3
    assert sorted(d.name for d in Path(tmp_path).iterdir()) == ["step_2",
                                                                "step_3"]


def test_checkpoint_corruption_detected(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    leaf = next(Path(tmp_path, "step_1").glob("leaf_*.npy"))
    blob = bytearray(leaf.read_bytes())
    blob[-1] ^= 0xFF
    leaf.write_bytes(bytes(blob))
    with pytest.raises(WAL.SnapshotError, match="corruption"):
        mgr.restore(tree)


def test_checkpoint_partial_save_invisible(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    orphan = tmp_path / "step_9.tmp-777"
    orphan.mkdir()
    (orphan / "leaf_0.npy").write_bytes(b"torn")
    mgr = CheckpointManager(str(tmp_path))   # GCs the orphan on open
    assert not orphan.exists()
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(rng))


def test_checkpoint_async_save(tmp_path, rng):
    from repro.checkpoint import CheckpointManager
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(7, tree, blocking=False)
    mgr.wait()
    assert Path(path).is_dir()
    restored, step = mgr.restore(tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(restored["w"]))
