"""Property test (ISSUE 7 satellite 2): ANY op stream crashed at ANY
byte restores to the sequential oracle.

Hypothesis drives a random mixed insert/delete stream and a random
crash offset into the WAL bytes it produced; `restore()` of the crashed
copy must answer a full-keyspace lookup and a range sweep bitwise-
identically to a fresh engine fed the durable op prefix. This is the
generalization of the hand-picked boundaries in test_crash_points.py —
the crash offset here lands anywhere: inside the magic, mid-header,
mid-payload, or at a record boundary."""
import os
import shutil

import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.engine import wal as WAL
from repro.engine.engine import SLSM

from harness import (apply_ops, assert_same_answers, crash_copy,
                     durable_write_ops, probe_answers, small_params)

KEYS = 512            # small keyspace: collisions + tombstone overlap


def _ops_strategy():
    op = st.tuples(
        st.sampled_from(["insert", "insert", "insert", "delete"]),
        st.lists(st.integers(0, KEYS - 1), min_size=1, max_size=40),
        st.integers(0, 1 << 20))
    return st.lists(op, min_size=1, max_size=10)


@settings(max_examples=20, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(ops=_ops_strategy(), crash_frac=st.floats(0.0, 1.0), data=st.data())
def test_random_stream_random_crash_restores_to_oracle(
        tmp_path_factory, ops, crash_frac, data):
    p = small_params()
    base = str(tmp_path_factory.mktemp("prop"))
    durdir = os.path.join(base, "ref")
    dur = WAL.Durability(durdir, fsync=False, snapshot_every_bytes=1 << 30)
    drv = SLSM(p, durability=dur)
    stream = []
    for kind, keys, seed in ops:
        k = np.asarray(keys, np.int32)
        if kind == "insert":
            v = ((k.astype(np.int64) * 2654435761 + seed)
                 % (1 << 20)).astype(np.int32)
            stream.append(("insert", k, v))
        else:
            stream.append(("delete", k, None))
    # optionally snapshot mid-stream so the crash also exercises the
    # watermark path
    snap_at = data.draw(st.one_of(
        st.none(), st.integers(0, len(stream) - 1)), label="snap_at")
    for i, (kind, k, v) in enumerate(stream):
        if kind == "insert":
            drv.insert(k, v)
        else:
            drv.delete(k)
        if snap_at is not None and i == snap_at:
            drv.snapshot()
    dur.close()
    wal_path = os.path.join(durdir, "wal.log")
    total = os.path.getsize(wal_path)
    cut = int(round(crash_frac * total))
    dst = os.path.join(base, "crashed")
    crash_copy(durdir, dst, cut=cut)
    j = durable_write_ops(os.path.join(dst, "wal.log"))
    # explicit params: a cut inside the magic/META leaves no fingerprint
    # to resolve them from (that path raises, covered in test_wal.py)
    restored = SLSM.restore(dst, params=p)
    # the oracle: a fresh non-durable engine fed the durable prefix
    oracle = SLSM(p)
    apply_ops(oracle, stream, upto=j)
    assert_same_answers(probe_answers(restored, key_space=KEYS),
                        probe_answers(oracle, key_space=KEYS))
    shutil.rmtree(base, ignore_errors=True)
