"""Sharded recovery parity (ISSUE 7 satellite 3): the driver-boundary
WAL makes single-tree and sharded recovery interchangeable.

Both drivers log writes *before* shard routing, so two engines fed the
same op stream produce identical WRITE/RETUNE record streams (the META
fingerprints differ — driver kind and shard count — which is why the
comparisons below are per-record, not byte-for-byte). Consequently a
crash at the same record index leaves both logs with the same durable
prefix, and both `restore()`s must answer identically."""
import os
from pathlib import Path

import numpy as np
import pytest

from repro.engine import wal as WAL
from repro.engine.engine import SLSM
from repro.engine.sharded import ShardedSLSM

from harness import (apply_ops, assert_same_answers, make_engine,
                     probe_answers, small_params, write_stream)


def _wal(ref):
    return os.path.join(ref["dir"], "wal.log")


def test_drivers_log_identical_record_streams(harness):
    """Same op stream -> same (kind, payload) sequence in both WALs;
    only the META fingerprint distinguishes them."""
    single = harness.reference("single", "jnp")
    sharded = harness.reference("sharded", "jnp")
    s_recs = [(r.kind, r.payload) for r, _, _ in single["offsets"]]
    h_recs = [(r.kind, r.payload) for r, _, _ in sharded["offsets"]]
    assert s_recs[0][0] == h_recs[0][0] == WAL.REC_META
    assert s_recs[0][1] != h_recs[0][1]          # fingerprints differ
    assert s_recs[1:] == h_recs[1:]              # op streams identical
    assert all(k in WAL.WRITE_KINDS for k, _ in s_recs[1:])


@pytest.mark.parametrize("record_index", [2, 7, -1])
def test_crash_parity_at_same_record(harness, record_index):
    """Crash both drivers at the end (and mid-body) of the same WRITE
    record: their restores answer identically (found-lane values — the
    not-found padding differs by driver class)."""
    refs = {d: harness.reference(d, "jnp") for d in ("single", "sharded")}
    answers = {}
    for driver, ref in refs.items():
        writes = [(r, s, e) for r, s, e in ref["offsets"]
                  if r.kind in WAL.WRITE_KINDS]
        rec, start, end = writes[record_index]
        for tag, cut in (("end", end), ("mid", start + WAL._HEADER.size + 2)):
            drv, j = harness.restore_at(ref, driver, cut=cut)
            answers.setdefault(tag, {})[driver] = (probe_answers(drv), j)
    for tag, by_driver in answers.items():
        (sa, sj), (ha, hj) = by_driver["single"], by_driver["sharded"]
        assert sj == hj, f"durable prefixes diverged at cut {tag!r}"
        assert_same_answers(sa, ha, strict_vals=False)


def test_torn_final_record_dropped_cleanly(harness, tmp_path):
    """A torn final record is invisible to recovery (CRC rejects it, no
    partial apply) and physically truncated when a writer reattaches —
    on both drivers."""
    for driver in ("single", "sharded"):
        ref = harness.reference(driver, "jnp")
        writes = [(r, s, e) for r, s, e in ref["offsets"]
                  if r.kind in WAL.WRITE_KINDS]
        _, start, end = writes[-1]
        cut = end - 5                      # mid-payload: CRC must reject
        drv, j = harness.restore_at(ref, driver, cut=cut)
        assert j == len(writes) - 1
        want = harness.oracle(driver, "jnp", False, ref["ops"], j)
        assert_same_answers(probe_answers(drv), want)
        # the keys of the torn record are NOT partially visible
        torn_keys = WAL.decode_write(writes[-1][0].payload)[0]
        prefix_keys = np.concatenate(
            [WAL.decode_write(r.payload)[0] for r, _, _ in writes[:-1]])
        only_torn = np.setdiff1d(torn_keys, prefix_keys)
        if only_torn.size:
            _, found = drv.lookup_many(only_torn.astype(np.int32))
            assert not np.asarray(found).any()
        # a reattaching writer truncates the torn bytes away
        drv.durability.sync()
        w = drv.durability.writer
        assert w.size >= start             # resumed past the good prefix
        records, good = WAL.read_wal(_wal({"dir": str(drv.durability.dir)}))
        assert all(r.seqno == i for i, r in enumerate(records))
        drv.durability.close()


def test_sharded_restore_recovers_shard_count(harness, tmp_path):
    """`ShardedSLSM.restore` rebuilds the logged shard count without the
    caller re-supplying it, and a mismatched explicit engine attach is
    rejected by the fingerprint check."""
    p = small_params()
    dur = WAL.Durability(tmp_path, fsync=False)
    drv = ShardedSLSM(p, n_shards=2, durability=dur)
    ops = write_stream(n_ops=6)
    apply_ops(drv, ops)
    dur.close()
    got = ShardedSLSM.restore(str(tmp_path))
    assert got.S == 2
    assert_same_answers(probe_answers(got), probe_answers(drv))
    with pytest.raises(ValueError, match="different engine"):
        ShardedSLSM(p, n_shards=4, durability=str(tmp_path))


def test_cross_driver_restore_rejected(harness, tmp_path):
    """Restoring a sharded WAL with the single-tree driver class (or
    vice versa) fails the fingerprint check instead of replaying into
    the wrong engine shape."""
    p = small_params()
    dur = WAL.Durability(tmp_path, fsync=False)
    drv = SLSM(p, durability=dur)
    apply_ops(drv, write_stream(n_ops=4))
    dur.close()
    with pytest.raises(ValueError, match="different engine"):
        ShardedSLSM.restore(str(tmp_path))
