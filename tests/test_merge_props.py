"""HeapMerge hypothesis sweep: sort-based, rank-based, and the Pallas
tournament agree on arbitrary run sets — module degrades to a skip when
hypothesis is not installed."""
import pytest

pytest.importorskip("hypothesis")

import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import runs as RU
from repro.kernels.heap_merge import heap_merge_op
from test_merge import make_runs, oracle_merge


@settings(max_examples=25, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(k=st.integers(2, 5), cap=st.sampled_from([16, 64, 96]),
       seed=st.integers(0, 10**6), drop=st.booleans())
def test_merge_paths_agree(k, cap, seed, drop):
    rng = np.random.default_rng(seed)
    K, V, S = make_runs(rng, k, cap)
    expect = oracle_merge(np.asarray(K), np.asarray(V), np.asarray(S), drop)

    for fn in (RU.merge_runs, RU.merge_kway_ranked, heap_merge_op):
        mk, mv, ms, cnt = fn(K, V, S, drop)
        got = list(zip(np.asarray(mk)[:int(cnt)].tolist(),
                       np.asarray(mv)[:int(cnt)].tolist(),
                       np.asarray(ms)[:int(cnt)].tolist()))
        assert got == expect, fn.__name__
