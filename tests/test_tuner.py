"""Adaptive memory/filter tuner tests (ISSUE 4, DESIGN.md §9).

Load-bearing properties:
  * every allocation the tuner can emit prices within its byte budget,
    and its per-level Bloom geometry keeps the *measured* FP rate within
    2x of the analytic bound (the acceptance bar for the Monkey-style
    per-level allocation);
  * with the tuner disabled (static policy) the engine is the pre-tuner
    engine: p_active IS p and no RETUNE ever becomes pending;
  * with tuning enabled, answers stay oracle-exact through every retune
    — mid-stream and after the drain() barrier — on both drivers and
    both backends (the drain-equivalence acceptance bar);
  * the effective-knob plumbing (r_eff, fence_stride, eps_per_level,
    skip_empty) changes performance shape only, never answers.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import SLSMParams, TuningPolicy
from repro.core import bloom as BL
from repro.core.oracle import DictOracle
from repro.engine import SLSM, ShardedSLSM
from repro.engine.read_path import lookup_batch
from repro.engine.tuner import (BALANCED, READ, WRITE, ReadModePolicy,
                                allocation_bytes, build_presets,
                                monkey_eps_per_level)

SMALL = dict(R=4, Rn=32, eps=1e-2, D=3, m=1.0, mu=8, max_levels=3,
             max_range=2048, cand_factor=16)


def adaptive_params(**over):
    pol = over.pop("tuning", TuningPolicy(mode="adaptive", interval=64,
                                          eps_floor=1e-3))
    return SLSMParams(**{**SMALL, **over, "tuning": pol})


def _drive_mixed(t, o, seed, rounds=8, key_space=400):
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        n = int(rng.integers(8, 60))
        ks = rng.integers(0, key_space // 2, n).astype(np.int32) * 2
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
        dels = rng.integers(0, key_space // 2,
                            int(rng.integers(1, 6))).astype(np.int32) * 2
        t.delete(dels)
        o.delete(dels)
    return np.arange(0, key_space, dtype=np.int32)


# -- allocations and the byte model -----------------------------------------

def test_presets_fit_budget_and_balanced_is_identity():
    p = adaptive_params()
    presets = build_presets(p)
    budget = allocation_bytes(p, presets[BALANCED])
    for alloc in presets.values():
        assert allocation_bytes(p, alloc) <= budget, alloc.name
    bal = presets[BALANCED]
    assert bal.r_eff == p.R and bal.eps_mem == p.eps
    assert bal.eps_per_level == (p.eps,) * p.max_levels
    assert bal.apply(p).level_eps(0) == p.eps
    # read frees write-buffer bytes; write frees filter bytes
    assert presets[READ].r_eff < presets[BALANCED].r_eff
    assert presets[WRITE].eps_per_level[0] > bal.eps_per_level[0]


def test_monkey_allocation_shape_and_floor():
    """Monkey-style: deeper (geometrically larger) levels get higher FP
    rates (fewer bits per element), bounded by the floor and 0.5."""
    p = adaptive_params()
    floor = min(p.eps, p.tuning.eps_floor)
    eps = monkey_eps_per_level(p, 10**9, floor)   # unconstrained budget
    assert eps[0] == floor                        # densest profile: base
    growth = max(2, p.disk_runs_merged)           # at the floor, shape
    assert eps[1] == pytest.approx(floor * growth)  # eps_l = base * T^l
    bal_bytes = sum(
        p.D * p.bloom_geometry(p.level_cap(l), p.eps)[1] * 4
        for l in range(p.max_levels))
    eps = monkey_eps_per_level(p, bal_bytes, floor)
    assert all(e1 <= e2 for e1, e2 in zip(eps, eps[1:]))   # deeper >= eps
    assert all(floor <= e <= 0.5 for e in eps)
    used = sum(p.D * p.bloom_geometry(p.level_cap(l), e)[1] * 4
               for l, e in enumerate(eps))
    assert used <= bal_bytes


def test_measured_fp_within_2x_of_analytic_per_allocation():
    """ISSUE-4 acceptance: for each per-level bit allocation the tuner
    can emit, a filter built at that geometry over a full run keeps its
    measured FP rate within 2x of the allocation's analytic eps."""
    p = adaptive_params()
    rng = np.random.default_rng(7)
    for alloc in build_presets(p).values():
        pa = alloc.apply(p)
        geoms = [(pa.level_cap(l), pa.level_eps(l),
                  pa.bloom_words_physical(pa.level_cap(l), pa.level_eps(l)))
                 for l in range(p.max_levels)]
        geoms.append((p.Rn, pa.mem_eps,
                      pa.bloom_words_physical(p.Rn, pa.mem_eps)))
        for n, eps_l, words in geoms:
            bits, _, k = pa.bloom_geometry(n, eps_l)
            # full-load worst case: n distinct even keys
            keys = (rng.choice(2**28, size=n, replace=False) * 2).astype(
                np.int32)
            filt = BL.bloom_build(jnp.asarray(keys),
                                  jnp.ones((n,), bool), words, k, bits)
            n_probe = max(20_000, int(50 / eps_l))
            n_probe = min(n_probe, 400_000)
            absent = (rng.integers(0, 2**28, n_probe) * 2 + 1).astype(
                np.int32)
            fp = float(np.asarray(
                BL.bloom_probe(filt, jnp.asarray(absent), k, bits)).mean())
            assert fp <= 2.0 * eps_l, (alloc.name, n, eps_l, fp)


def test_presets_fit_budget_even_for_sparse_static_eps():
    """Regression: an adaptive engine whose configured eps is sparser
    than eps_write must still construct — the write preset takes the
    sparser of the two per site instead of densifying over budget."""
    p = adaptive_params(eps=0.1)
    presets = build_presets(p)
    budget = allocation_bytes(p, presets[BALANCED])
    for alloc in presets.values():
        assert allocation_bytes(p, alloc) <= budget, alloc.name
    assert presets[WRITE].eps_per_level[0] >= p.eps   # never denser
    SLSM(p)   # end-to-end: construction no longer raises


def test_read_switch_gated_on_disk_probe_traffic():
    """The read-optimized fold only pays when sampled reads reach the
    disk levels; with samples showing zero disk candidates the
    controller must not switch to READ (and must with traffic)."""
    p = adaptive_params()
    t = SLSM(p)
    tun = t.tuner
    tun.note_probe_stats(np.zeros(p.max_levels, np.int64),
                         np.zeros(p.max_levels, np.int64))
    tun.read_frac = 0.99
    tun.note_reads(10 * p.tuning.interval)
    tun._win_reads = 10 * p.tuning.interval
    tun.decide()
    assert tun.target != READ            # all-memtable reads: no fold
    tun.note_probe_stats(np.ones(p.max_levels, np.int64),
                         np.zeros(p.max_levels, np.int64))
    tun.note_reads(10 * p.tuning.interval)
    tun._win_reads = 10 * p.tuning.interval
    tun.decide()
    assert tun.target == READ            # disk traffic observed


def test_read_mode_policy_is_depth_aware():
    p = adaptive_params()
    pol = ReadModePolicy()
    assert pol.needs_spill(p, 1, level=0)         # fold even one L0 run
    assert not pol.needs_spill(p, p.D - 1, level=1)
    assert pol.needs_spill(p, p.D, level=2)       # deep: the paper's rule
    assert set(pol.spill_sizes(p)) == set(range(1, p.D + 1))


# -- static mode is the pre-tuner engine ------------------------------------

def test_static_mode_is_inert():
    t = SLSM(SLSMParams(**SMALL))
    assert t.p_active is t.p
    assert not t.tuner.enabled and not t.tuner.pending
    o = DictOracle()
    qs = _drive_mixed(t, o, seed=3)
    t.tuner.note_reads(10**6)
    t.tuner.decide()                 # inert: no decision machinery runs
    assert not t.tuner.pending and t.stats["retunes"] == 0
    got, found = t.lookup_many(qs)
    ev, ef = o.lookup(qs)
    assert (found == ef).all() and (got[ef] == ev[ef]).all()


def test_effective_knobs_do_not_change_answers():
    """r_eff / fence_stride / eps_per_level / eps_mem reshape cost, not
    results: engines differing only in those knobs answer identically."""
    base = SLSMParams(**SMALL, merge_budget=1)
    variants = [
        SLSMParams(**{**SMALL, "merge_budget": 1, "r_eff": 2}),
        SLSMParams(**{**SMALL, "merge_budget": 1, "fence_stride": 4}),
        SLSMParams(**{**SMALL, "merge_budget": 1,
                      "eps_per_level": (5e-3, 2e-2, 0.25)}),
        SLSMParams(**{**SMALL, "merge_budget": 1, "eps_mem": 0.2}),
    ]
    ref, oref = SLSM(base), DictOracle()
    qs = _drive_mixed(ref, oref, seed=11)
    want_v, want_f = ref.lookup_many(qs)
    want_range = ref.range(0, 300)
    for pv in variants:
        tv = SLSM(pv)
        _drive_mixed(tv, DictOracle(), seed=11)
        got_v, got_f = tv.lookup_many(qs)
        assert (got_f == want_f).all()
        assert (got_v[want_f] == want_v[want_f]).all()
        rk, rv = tv.range(0, 300)
        assert (rk == want_range[0]).all() and (rv == want_range[1]).all()


def test_skip_empty_gate_is_exact():
    t = SLSM(SLSMParams(**SMALL, merge_budget=1))
    o = DictOracle()
    qs = _drive_mixed(t, o, seed=5)
    v0, f0 = lookup_batch(t.p, t.state, jnp.asarray(qs), False, False)
    v1, f1 = lookup_batch(t.p, t.state, jnp.asarray(qs), False, True)
    assert (np.asarray(f0) == np.asarray(f1)).all()
    assert (np.asarray(v0) == np.asarray(v1)).all()


# -- adaptive correctness: the drain-equivalence acceptance bar -------------

def _shifting_stream(t, o, seed, key_space=600):
    """Write burst -> read burst -> write burst: forces the controller
    through write-, read-, and back-to-write-optimized allocations."""
    rng = np.random.default_rng(seed)
    probe = np.arange(0, key_space, dtype=np.int32)
    for _ in range(6):                       # write-heavy
        ks = rng.integers(0, key_space // 2, 80).astype(np.int32) * 2
        vs = rng.integers(-99, 99, 80).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
    for r in range(10):                      # read-heavy (+ trickle)
        got, found = t.lookup_many(probe)
        ev, ef = o.lookup(probe)
        assert (found == ef).all(), f"read round {r}"
        assert (got[ef] == ev[ef]).all(), f"read round {r}"
        if r % 3 == 2:
            ks = rng.integers(0, key_space // 2, 8).astype(np.int32) * 2
            t.insert(ks, ks)
            o.insert(ks, ks)
    for _ in range(4):                       # back to write-heavy
        ks = rng.integers(0, key_space // 2, 80).astype(np.int32) * 2
        vs = rng.integers(-99, 99, 80).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
    return probe


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("budget", [0, 1])
def test_adaptive_single_tree_oracle_exact_through_retunes(backend, budget):
    p = adaptive_params(backend=backend, merge_budget=budget)
    t, o = SLSM(p), DictOracle()
    probe = _shifting_stream(t, o, seed=23)
    assert t.stats["retunes"] >= 1, "stream must exercise the tuner"
    t.drain()
    assert not t.scheduler.backlog            # retunes drain too
    got, found = t.lookup_many(probe)
    ev, ef = o.lookup(probe)
    assert (found == ef).all() and (got[ef] == ev[ef]).all()
    rk, rv = t.range(0, 400)
    ok_, ov = o.range(0, 400)
    assert (rk == ok_).all() and (rv == ov).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_adaptive_sharded_oracle_exact_through_retunes(backend):
    p = adaptive_params(backend=backend, merge_budget=1)
    t, o = ShardedSLSM(p, n_shards=2), DictOracle()
    probe = _shifting_stream(t, o, seed=29)
    assert t.stats["retunes"] >= 1
    t.drain()
    got, found = t.lookup(probe)
    ev, ef = o.lookup(probe)
    assert (found == ef).all() and (got[ef] == ev[ef]).all()
    rk, rv = t.range(0, 400)
    ok_, ov = o.range(0, 400)
    assert (rk == ok_).all() and (rv == ov).all()


def test_adaptive_budgeted_matches_sync_static_after_drain():
    """A tuned, budgeted engine and a plain synchronous engine fed the
    same ops answer identically at rest — tuning moves cost, not data."""
    pa = adaptive_params(merge_budget=2)
    ta, oa = SLSM(pa), DictOracle()
    ts = SLSM(SLSMParams(**SMALL))           # sync, static, pre-tuner
    probe = _shifting_stream(ta, oa, seed=31)
    _shifting_stream(ts, DictOracle(), seed=31)
    ta.drain()
    va, fa = ta.lookup_many(probe)
    vs, fs = ts.lookup_many(probe)
    assert (fa == fs).all() and (va[fa] == vs[fa]).all()


def test_retune_rebuild_leaves_no_false_negatives():
    """Filters rebuilt by a RETUNE must keep the Bloom no-false-negative
    contract: every resident key still gate-passes its level."""
    p = adaptive_params(merge_budget=1)
    t, o = SLSM(p), DictOracle()
    rng = np.random.default_rng(41)
    ks = (rng.choice(5000, size=600, replace=False) * 2).astype(np.int32)
    t.insert(ks, ks + 1)
    o.insert(ks, ks + 1)
    for name in (WRITE, READ, BALANCED, WRITE):
        t.tuner.target = name
        t.apply_retune()
        assert t.tuner.active == name
        got, found = t.lookup_many(ks)
        assert found.all() and (got == ks + 1).all()
    assert t.p_active.level_eps(0) == t.tuner.presets[WRITE].eps_per_level[0]


def test_tuner_telemetry_and_stats_counters():
    p = adaptive_params(merge_budget=1)
    t, o = SLSM(p), DictOracle()
    probe = _shifting_stream(t, o, seed=43)
    assert t.stats["reads"] > 0 and t.stats["writes"] > 0
    assert t.stats["retunes"] >= 1
    # probe telemetry: candidates >= hits, fp estimate in [0, 1]
    assert (t.tuner.level_candidates >= t.tuner.level_hits).all()
    fp = t.tuner.level_fp_observed
    assert ((fp >= 0) & (fp <= 1)).all()
    assert t.tuner.budget_bytes > 0
    del probe


def test_adaptive_rejects_bad_policy():
    with pytest.raises(ValueError, match="tuning mode"):
        TuningPolicy(mode="sometimes")
    with pytest.raises(ValueError, match="interval"):
        TuningPolicy(interval=0)
    with pytest.raises(ValueError, match="r_eff"):
        SLSMParams(**{**SMALL, "r_eff": SMALL["R"] + 1})
    with pytest.raises(ValueError, match="fence_stride"):
        SLSMParams(**{**SMALL, "fence_stride": 3})
    with pytest.raises(ValueError, match="eps_per_level"):
        SLSMParams(**{**SMALL, "eps_per_level": (0.1,)})
