"""Range-scan engine tests (ISSUE 5, DESIGN.md §10).

Load-bearing properties:
  * `range_many` is oracle-exact — overwrites, tombstones, empty
    windows, windows straddling stage/memory-runs/disk-levels — on both
    backends x both drivers, mid-stream, through a drain() barrier, and
    (adaptive engines) through RETUNE allocation switches;
  * the truncated-flag contract: a result row is ALWAYS a correct
    sorted prefix of the window's live keys; the flag is False iff the
    row is the whole window (it is raised past max_range live keys or
    on a `range_cand` budget overflow);
  * sharded and single-tree `range_many` agree bit-for-bit (disjoint
    hash shards, on-device merge);
  * the `range_merge` kernel matches its jnp reference on adversarial
    segment layouts (the per-kernel sweep lives in test_kernels.py
    style, here beside its consumers).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.oracle import DictOracle
from repro.core.params import KEY_EMPTY, SLSMParams, TuningPolicy
from repro.engine import SLSM, ShardedSLSM
from repro.kernels.range_merge import range_merge_op, range_merge_ref

SMALL = dict(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=3,
             max_range=64)


def small_params(**over):
    return SLSMParams(**{**SMALL, **over})


def _drive(t, o, seed, key_space=600, rounds=6, deletes=True):
    """Mixed insert/overwrite/delete stream pushing data through every
    structure tier (stage, memory runs, multiple disk levels)."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        n = int(rng.integers(6, 20))
        ks = rng.integers(0, key_space // 2, n).astype(np.int32) * 2
        vs = rng.integers(-50, 50, n).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
        if deletes:
            dels = rng.integers(0, key_space // 2,
                                int(rng.integers(1, 4))).astype(np.int32) * 2
            t.delete(dels)
            o.delete(dels)


WINDOWS = [(0, 600), (0, 0), (100, 101), (550, 700), (-50, 40), (300, 200),
           (37, 411)]


def _check_windows(t, o, windows=WINDOWS):
    """range_many rows must be exact prefixes of the oracle's windows,
    and complete wherever the truncated flag is clear."""
    ks, vs, cs, trunc = t.range_many(windows)
    for i, (lo, hi) in enumerate(windows):
        ko, vo = o.range(lo, hi)
        n = int(cs[i])
        if not trunc[i]:
            assert n == len(ko), (i, n, len(ko))
        np.testing.assert_array_equal(ks[i][:n], ko[:n], err_msg=str(i))
        np.testing.assert_array_equal(vs[i][:n], vo[:n], err_msg=str(i))
        assert (ks[i][n:] == KEY_EMPTY).all()


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("budget", [0, 1])
def test_range_many_oracle_exact_single_tree(backend, budget):
    t = SLSM(small_params(backend=backend, merge_budget=budget))
    o = DictOracle()
    _drive(t, o, seed=3)
    _check_windows(t, o)          # mid-stream: pending merges visible
    t.drain()
    _check_windows(t, o)          # at rest: drain barrier equivalence


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_range_many_oracle_exact_sharded(backend):
    s = ShardedSLSM(small_params(backend=backend, merge_budget=1),
                    n_shards=4)
    o = DictOracle()
    _drive(s, o, seed=5)
    _check_windows(s, o)
    s.drain()
    _check_windows(s, o)


def test_sharded_matches_single_tree_bitwise():
    t = SLSM(small_params())
    s = ShardedSLSM(small_params(), n_shards=4)
    o = DictOracle()
    _drive(t, o, seed=7)
    _drive(s, DictOracle(), seed=7)
    kt, vt, ct, rt = t.range_many(WINDOWS)
    ks, vs, cs, rs = s.range_many(WINDOWS)
    np.testing.assert_array_equal(ct, cs)
    np.testing.assert_array_equal(rt, rs)
    np.testing.assert_array_equal(kt, ks)
    np.testing.assert_array_equal(vt, vs)


def test_window_straddles_every_tier():
    """A window covering keys resident in the stage, the sealed memory
    runs, and multiple disk levels at once must merge them newest-wins."""
    t, o = SLSM(small_params()), DictOracle()
    ks = np.arange(0, 80, 2, dtype=np.int32)      # 40 keys -> disk
    t.insert(ks, ks)
    o.insert(ks, ks)
    t.insert(ks[:10], ks[:10] * 100)              # overwrites, shallower
    o.insert(ks[:10], ks[:10] * 100)
    t.delete(ks[20:25])
    o.delete(ks[20:25])
    t.insert(np.asarray([81], np.int32), np.asarray([7], np.int32))  # stage
    o.insert([81], [7])
    assert t.n_levels >= 1                        # data actually spilled
    _check_windows(t, o, [(0, 100)])


def test_overwrites_and_tombstones_never_evict_live_keys():
    """The PR 3 regression, through the new engine: stale versions and
    tombstones filling a window must cancel before the max_range cut."""
    p = small_params(max_range=16)
    t, o = SLSM(p), DictOracle()
    keys = np.arange(0, 40, dtype=np.int32)
    t.insert(keys, keys)
    o.insert(keys, keys)
    t.delete(keys[:32])
    o.delete(keys[:32])
    k1, v1, trunc = t.range(0, 80, return_truncated=True)
    k2, v2 = o.range(0, 80)
    assert not trunc and len(k2) == 8
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)


def test_truncation_flag_at_max_range():
    t = SLSM(small_params(max_range=16))
    ks = np.arange(0, 64, dtype=np.int32)
    t.insert(ks, ks)
    k, v, trunc = t.range(0, 64, return_truncated=True)
    assert trunc and len(k) == 16
    np.testing.assert_array_equal(k, ks[:16])
    k, v, trunc = t.range(0, 10, return_truncated=True)
    assert not trunc and len(k) == 10


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_range_cand_overflow_is_prefix_exact_and_flagged(backend):
    """A finite candidate budget may cut a scan short, but the result
    must stay a correct prefix and the flag must be raised."""
    p = small_params(backend=backend, max_range=64, range_cand=16)
    t, o = SLSM(p), DictOracle()
    ks = np.arange(0, 100, 2, dtype=np.int32)
    t.insert(ks, ks * 3)
    o.insert(ks, ks * 3)
    k, v, trunc = t.range(0, 200, return_truncated=True)
    ko, vo = o.range(0, 200)
    assert trunc, "budget overflow must raise the truncated flag"
    np.testing.assert_array_equal(k, ko[:len(k)])
    np.testing.assert_array_equal(v, vo[:len(k)])
    # narrow windows stay under the budget: exact and unflagged
    k, v, trunc = t.range(10, 22, return_truncated=True)
    ko, vo = o.range(10, 22)
    assert not trunc
    np.testing.assert_array_equal(k, ko)
    np.testing.assert_array_equal(v, vo)


def test_range_cand_validation():
    with pytest.raises(ValueError, match="range_cand"):
        small_params(range_cand=0)
    assert small_params(range_cand=None).range_cand_eff(0) == \
        small_params().stage_cap + 2 * 8


def test_range_device_matches_range():
    t, o = SLSM(small_params()), DictOracle()
    _drive(t, o, seed=11)
    k, v, c, trunc = t.range_device(0, 600)
    kk, vv = np.asarray(k), np.asarray(v)
    n = int(c)
    rk, rv, rt = t.range(0, 600, return_truncated=True)
    assert bool(trunc) == rt and n == len(rk)
    np.testing.assert_array_equal(kk[:n], rk)
    np.testing.assert_array_equal(vv[:n], rv)
    # sharded driver honors the same device contract
    s = ShardedSLSM(small_params(), n_shards=2)
    _drive(s, DictOracle(), seed=11)
    sk, sv, sc, st_ = s.range_device(0, 600)
    np.testing.assert_array_equal(np.asarray(sk)[:int(sc)], rk)


@pytest.mark.parametrize("engine", ["single", "sharded"])
def test_range_many_through_retune_and_drain(engine):
    """Adaptive engines must answer scans exactly across RETUNE
    allocation switches (filters/fence views swap under the scan)."""
    pol = TuningPolicy(mode="adaptive", interval=64, eps_floor=1e-3)
    p = SLSMParams(R=4, Rn=32, eps=1e-2, D=3, m=1.0, mu=8, max_levels=3,
                   max_range=2048, merge_budget=1, tuning=pol)
    if engine == "single":
        t = SLSM(p)
    else:
        t = ShardedSLSM(p, n_shards=2)
    o = DictOracle()
    rng = np.random.default_rng(23)
    probe_windows = [(0, 400), (50, 250), (0, 0)]
    for _ in range(6):                       # write burst
        ks = rng.integers(0, 200, 80).astype(np.int32) * 2
        vs = rng.integers(-99, 99, 80).astype(np.int32)
        t.insert(ks, vs)
        o.insert(ks, vs)
    for r in range(10):                      # read burst flips the tuner
        t.lookup_many(np.arange(0, 400, dtype=np.int32))
        _check_windows(t, o, probe_windows)
        if r % 3 == 2:
            ks = rng.integers(0, 200, 8).astype(np.int32) * 2
            t.insert(ks, ks)
            o.insert(ks, ks)
    assert t.stats["retunes"] >= 1, "stream must exercise the tuner"
    t.drain()
    _check_windows(t, o, probe_windows)


def test_range_many_empty_batch_and_bucketing():
    t = SLSM(small_params())
    k, v, c, trunc = t.range_many([])
    assert k.shape == (0, t.p.max_range) and c.shape == (0,)
    t.insert(np.asarray([2, 4], np.int32), np.asarray([1, 2], np.int32))
    # odd batch sizes ride the padded bucket grid and trim back
    for q in (1, 3, 9):
        wins = [(0, 10)] * q
        k, v, c, trunc = t.range_many(wins)
        assert k.shape == (q, t.p.max_range)
        assert (c == 2).all() and not trunc.any()


# -- the range_merge kernel against its jnp oracle ---------------------------

@pytest.mark.parametrize("q,widths", [
    (1, [16]), (2, [8, 8, 8]), (3, [0, 5, 0, 9, 2]),
    (4, [32] * 7), (1, [1] * 12),
])
def test_range_merge_kernel_matches_ref(rng, q, widths):
    cand = sum(widths) + int(rng.integers(0, 4))
    cand = max(cand, 1)
    for drop in (False, True):
        k = np.full((q, cand), KEY_EMPTY, np.int32)
        v = np.zeros((q, cand), np.int32)
        wt = np.zeros((q, cand), np.int8)
        s = np.zeros((q, cand), np.int32)
        off = np.zeros((q, len(widths) + 1), np.int32)
        seq = 0
        for qi in range(q):
            pos = 0
            for pi, w in enumerate(widths):
                e = int(rng.integers(0, w + 1))
                k[qi, pos:pos + e] = np.sort(
                    rng.integers(0, 60, e)).astype(np.int32)
                dels = rng.random(e) < 0.3        # weight -1 retractions
                v[qi, pos:pos + e] = np.where(
                    dels, 0, rng.integers(0, 100, e)).astype(np.int32)
                wt[qi, pos:pos + e] = np.where(dels, -1, 1)
                s[qi, pos:pos + e] = np.arange(seq, seq + e)
                seq += e
                pos += e
                off[qi, pi + 1] = pos
        args = (jnp.asarray(k), jnp.asarray(v), jnp.asarray(wt),
                jnp.asarray(s), jnp.asarray(off), drop)
        got = range_merge_op(*args)
        want = range_merge_ref(*args)
        for name, g, w in zip(("keys", "vals", "wts", "seqs", "keep"),
                              got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                          err_msg=f"{name} drop={drop}")
