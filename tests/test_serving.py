"""Serving-layer tests (ISSUE 6, DESIGN.md §11).

Load-bearing properties:
  * the oracle: a randomized interleaved op stream (insert / delete /
    lookup / range, multiple clients) served through the coalescing
    window + mixed-op tape is bitwise-equal — per ticket AND after the
    drain() barrier — to the same stream executed sequentially through
    the classic per-op driver calls, on both backends x both drivers;
    the per_request baseline mode satisfies the same oracle;
  * steady state never JITs: after `Server.warm()`, serving windows
    leave the tape interpreter's jit cache untouched;
  * the coalescer's hazard rule (only adjacent same-kind ops merge),
    capacity splitting, and scatter's result routing;
  * the WindowPolicy triggers and adaptive deadline, the Governor's
    credit accrual/cap/idle spend;
  * the closed-loop load generator and the stats() ledger (p999 +
    max-stall tail accounting the serving bench gates on);
  * the asyncio front-end round-trips a submit to its awaited result.
"""
import asyncio
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.params import KEY_EMPTY, SLSMParams
from repro.engine import SLSM, ShardedSLSM
from repro.engine import tape as TP
from repro.engine import sharded as SH
from repro.serve import (AsyncServer, Governor, Server, WindowPolicy,
                         closed_loop, coalesce, scatter, sustained_at_slo)

# max_levels=4 (vs the usual 3): the per_request baseline and the
# governor push the same stream through real compactions, and the tiny
# geometry otherwise overflows its deepest level mid-test
SMALL = dict(R=2, Rn=8, eps=0.02, D=2, m=1.0, mu=4, max_levels=4,
             max_range=64)


def small_params(**over):
    return SLSMParams(**{**SMALL, **over})


# -- the request stream ------------------------------------------------------

def _stream(seed, n_requests=36, key_space=400):
    """Randomized interleaved multi-op request stream: a short
    insert-only warmup, then mixed inserts / deletes / lookups (with
    guaranteed-miss `key|1` probes) / range scans."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        kind = ("insert" if i < 4 else
                rng.choice(["insert", "insert", "lookup", "lookup",
                            "delete", "range"]))
        if kind == "insert":
            n = int(rng.integers(1, 7))
            ks = (rng.integers(0, key_space // 2, n) * 2).astype(np.int32)
            vs = rng.integers(-50, 50, n).astype(np.int32)
            reqs.append(("insert", ks, vs))
        elif kind == "delete":
            ks = (rng.integers(0, key_space // 2,
                               int(rng.integers(1, 4))) * 2).astype(np.int32)
            reqs.append(("delete", ks, None))
        elif kind == "lookup":
            n = int(rng.integers(1, 7))
            ks = (rng.integers(0, key_space // 2, n) * 2).astype(np.int32)
            ks = np.where(rng.random(n) < 0.3, ks | 1, ks).astype(np.int32)
            reqs.append(("lookup", ks, None))
        else:
            n = int(rng.integers(1, 3))
            lo = rng.integers(0, key_space, n).astype(np.int32)
            hi = (lo + rng.integers(1, 48, n)).astype(np.int32)
            reqs.append(("range", lo, hi))
    return reqs


def _serve_sequential(tree, reqs):
    """The oracle: the same stream, one classic driver call per request,
    in submission order."""
    out = []
    for kind, a, b in reqs:
        if kind == "insert":
            tree.insert(a, b)
            out.append(None)
        elif kind == "delete":
            tree.delete(a)
            out.append(None)
        elif kind == "lookup":
            out.append(tree.lookup_many(a))
        else:
            out.append(tree.range_many(np.stack([a, b], axis=1)))
    return out


def _assert_result_equal(got, want, msg=""):
    if want is None:
        assert got is None, msg
        return
    assert len(got) == len(want), msg
    for gi, wi in zip(got, want):
        np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi),
                                      err_msg=msg)


def _run_server_oracle(build, reqs, mode):
    """Drive a Server over `reqs` (pumping mid-stream at odd intervals)
    and check every ticket against the sequential oracle, then check
    the post-drain read state agrees too."""
    ref_tree = build()
    ref = _serve_sequential(ref_tree, reqs)
    srv = Server(build(), window=WindowPolicy(max_ops=24), mode=mode)
    tickets = []
    for i, (kind, a, b) in enumerate(reqs):
        tickets.append(srv.submit(f"client-{i % 3}", kind, a, b))
        if i % 7 == 6:
            srv.pump(force=True)
    srv.drain()
    for i, (t, r) in enumerate(zip(tickets, ref)):
        assert t.done
        _assert_result_equal(t.result, r, msg=f"request {i} ({t.kind})")
    # post-drain barrier: both trees answer identically everywhere
    ref_tree.drain()
    probe = np.arange(0, 400, 2, dtype=np.int32)
    _assert_result_equal(srv.tree.lookup_many(probe),
                         ref_tree.lookup_many(probe), msg="post-drain lookup")
    _assert_result_equal(srv.tree.range_many([(0, 400), (37, 203)]),
                         ref_tree.range_many([(0, 400), (37, 203)]),
                         msg="post-drain range")
    return srv


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("sharded", [False, True])
def test_serving_oracle_coalesced(backend, sharded):
    p = small_params(backend=backend)

    def build():
        return ShardedSLSM(p, n_shards=2) if sharded else SLSM(p)

    srv = _run_server_oracle(build, _stream(seed=7), "coalesced")
    # the coalescer actually fused: fewer dispatches than requests
    assert srv.counters["dispatches"] < srv.counters["requests"]


def test_serving_oracle_per_request():
    p = small_params()
    srv = _run_server_oracle(lambda: SLSM(p), _stream(seed=11),
                             "per_request")
    # the baseline pays one driver call per request
    assert srv.counters["dispatches"] >= srv.counters["requests"]


def test_no_recompile_after_warm():
    """Steady-state serving never JITs: after warm(), windows reuse the
    precompiled tape grid on both drivers."""
    srv = Server(SLSM(small_params()))
    srv.warm()
    n0 = TP.tape_exec._cache_size()
    for kind, a, b in _stream(seed=3, n_requests=24):
        srv.submit("c", kind, a, b)
        srv.pump(force=True)
    srv.drain()
    assert TP.tape_exec._cache_size() == n0

    ssrv = Server(ShardedSLSM(small_params(), n_shards=2))
    ssrv.warm()
    s0 = SH._tape_exec_sharded._cache_size()
    for kind, a, b in _stream(seed=4, n_requests=24):
        ssrv.submit("c", kind, a, b)
        ssrv.pump(force=True)
    ssrv.drain()
    assert SH._tape_exec_sharded._cache_size() == s0


# -- coalescer ----------------------------------------------------------------

def _ticket(kind, keys, vals=None):
    keys = np.asarray(keys, np.int32)
    if vals is None:
        vals = np.zeros_like(keys)
    return SimpleNamespace(kind=kind, keys=keys,
                           vals=np.asarray(vals, np.int32))


def test_coalesce_hazard_ordering():
    """A write between two lookups is a hazard boundary: same-kind ops
    merge ONLY when adjacent, so chunk order = stream order."""
    p = small_params()
    tickets = [_ticket("lookup", [2, 4]), _ticket("insert", [6], [1]),
               _ticket("lookup", [6]), _ticket("lookup", [8])]
    chunks, places = coalesce(p, tickets)
    assert [c.kind for c in chunks] == ["lookup", "write", "lookup"]
    # the two adjacent lookups fused into the final chunk
    np.testing.assert_array_equal(chunks[2].keys, [6, 8])
    assert places[2] == [(2, 0, 1, 0)] and places[3] == [(2, 1, 1, 0)]


def test_coalesce_deletes_merge_with_inserts():
    """Deletes are weight -1 writes (DESIGN.md §13): adjacent
    insert+delete share one write chunk, the delete lanes carrying
    payload 0 and weight -1 beside the inserts' weight +1."""
    p = small_params()
    chunks, _ = coalesce(p, [_ticket("insert", [2, 4], [7, 8]),
                             _ticket("delete", [6])])
    assert len(chunks) == 1 and chunks[0].kind == "write"
    np.testing.assert_array_equal(chunks[0].keys, [2, 4, 6])
    np.testing.assert_array_equal(chunks[0].vals, [7, 8, 0])
    np.testing.assert_array_equal(chunks[0].wts, [1, 1, -1])


def test_coalesce_capacity_split_roundtrip():
    """A request larger than a slot's capacity splits across chunks;
    the placements reassemble it exactly and every chunk respects
    `chunk_capacity`."""
    p = small_params()     # Rn = 8 write/lookup lanes per slot
    keys = (np.arange(21, dtype=np.int32) + 1) * 2
    vals = np.arange(21, dtype=np.int32)
    chunks, places = coalesce(p, [_ticket("insert", keys, vals)])
    assert len(chunks) == 3
    assert all(len(c.keys) <= TP.chunk_capacity(p, c.kind) for c in chunks)
    got = np.concatenate([chunks[pl.chunk].keys[pl.lane:pl.lane + pl.n]
                          for pl in places[0]])
    np.testing.assert_array_equal(got, keys)
    assert [pl.off for pl in places[0]] == [0, 8, 16]


def test_scatter_routes_results():
    """scatter slices each chunk's result planes back onto the tickets
    that contributed the lanes (writes get None)."""
    p = small_params()
    tickets = [_ticket("insert", [2], [1]), _ticket("lookup", [4, 6]),
               _ticket("lookup", [8])]
    chunks, places = coalesce(p, tickets)
    assert [c.kind for c in chunks] == ["write", "lookup"]
    results = [1, (np.array([40, 60, 80]), np.array([True, False, True]))]
    scatter(tickets, places, results)
    assert tickets[0].result is None
    np.testing.assert_array_equal(tickets[1].result[0], [40, 60])
    np.testing.assert_array_equal(tickets[1].result[1], [True, False])
    np.testing.assert_array_equal(tickets[2].result[0], [80])
    np.testing.assert_array_equal(tickets[2].result[1], [True])


# -- window policy + governor -------------------------------------------------

def test_window_policy_triggers():
    wp = WindowPolicy(max_ops=16, wait_s=1e-3)
    assert not wp.should_close(0, 10.0)          # nothing pending
    assert wp.should_close(16, 0.0)              # size trigger
    assert not wp.should_close(1, 0.0)           # thin + fresh
    assert wp.should_close(1, 2e-3)              # time trigger


def test_window_policy_adapts():
    wp = WindowPolicy(max_ops=16, wait_s=1e-3)
    wp.closed(16)                                # full window -> wait up
    assert wp.wait_s > 1e-3
    wp = WindowPolicy(max_ops=16, wait_s=1e-3)
    wp.closed(1)                                 # thin timeout -> wait down
    assert wp.wait_s < 1e-3
    for _ in range(100):                         # clipped to the bounds
        wp.closed(0)
    assert wp.wait_s == pytest.approx(wp.min_wait_s)


class _FakeTree:
    """voluntary_steps stub with a bounded ready backlog."""

    def __init__(self, merge_budget=1, Rn=8, ready=100):
        self.p_active = SimpleNamespace(merge_budget=merge_budget, Rn=Rn)
        self.ready = ready
        self.ran = 0

    def voluntary_steps(self, budget):
        ran = min(budget, self.ready)
        self.ready -= ran
        self.ran += ran
        return ran


def test_governor_accrues_and_spends():
    """Credits accrue at merge_budget steps per Rn write ops; only whole
    steps are spent, fractions bank."""
    gov, tree = Governor(), _FakeTree(merge_budget=1, Rn=8)
    assert gov.window_done(tree, 4) == 0         # 0.5 credits banked
    assert gov.credits == pytest.approx(0.5)
    assert gov.window_done(tree, 4) == 1         # 1.0 -> one step
    assert gov.credits == pytest.approx(0.0)
    assert tree.ran == 1 and gov.steps_run == 1


def test_governor_credit_cap_and_idle():
    """A write burst cannot bank unbounded credits; idle pumps spend the
    free idle allowance."""
    gov = Governor(credit_cap=4.0)
    empty = _FakeTree(ready=0)
    gov.window_done(empty, 10_000)               # nothing ready to run
    assert gov.credits == pytest.approx(4.0)     # capped, stays banked
    busy = _FakeTree(ready=100)
    assert gov.window_done(busy, 0) == 4         # spent once work exists
    assert gov.idle(busy) == 1
    assert gov.idle_steps_run == 1 and gov.steps_run == 5


# -- load generator + accounting ----------------------------------------------

def test_closed_loop_and_stats():
    reqs = [SimpleNamespace(kind=k, keys=a, vals=b)
            for k, a, b in _stream(seed=5, n_requests=30)]
    srv = Server(SLSM(small_params()))
    srv.warm(full=False)
    pt = closed_loop(srv, reqs, concurrency=4)
    assert pt["clients"] == 4 and pt["requests"] == 30
    assert pt["ops"] == sum(r.keys.size for r in reqs)
    assert pt["ops_per_s"] > 0
    assert pt["max_stall_us"] >= pt["p999_us"] >= pt["p99_us"] > 0
    assert pt["dispatches"] <= pt["windows"] + 1
    srv.drain()
    st = srv.stats()
    assert set(st["clients"]) == {f"client-{c}" for c in range(4)}
    for ledger in list(st["clients"].values()) + [st["overall"]]:
        assert ledger["max_stall_us"] >= ledger["p999_us"] > 0
    assert st["counters"]["requests"] == 30
    assert st["governor"]["steps"] >= st["governor"]["idle_steps"] >= 0
    assert sustained_at_slo([pt], slo_p99_us=pt["p99_us"]) == pt["ops_per_s"]
    assert sustained_at_slo([pt], slo_p99_us=0.0) == 0.0


def test_submit_validates_at_the_boundary():
    srv = Server(SLSM(small_params()))
    with pytest.raises(ValueError):
        srv.submit("c", "upsert", [2])
    with pytest.raises(ValueError):
        srv.submit("c", "insert", [2, KEY_EMPTY], [1, 2])
    with pytest.raises(ValueError):
        srv.submit("c", "insert", [2, 4], [1])
    assert srv.pending == 0                      # nothing poisoned the window
    # the old reserved-value sentinel is now a legal payload (ISSUE 8)
    srv.submit("c", "insert", [2], [np.iinfo(np.int32).min])
    assert srv.pending == 1


def test_async_frontend_roundtrip():
    """The asyncio front-end resolves a submitted request to the same
    result the synchronous ticket carries."""
    srv = Server(SLSM(small_params()), window=WindowPolicy(max_ops=4))

    async def scenario():
        async with AsyncServer(srv, poll_s=1e-4) as front:
            await front.submit("a", "insert", np.int32([2, 4]),
                               np.int32([20, 40]))
            vals, found = await front.submit("a", "lookup",
                                             np.int32([2, 4, 5]))
            return np.asarray(vals), np.asarray(found)

    vals, found = asyncio.run(scenario())
    np.testing.assert_array_equal(found, [True, True, False])
    np.testing.assert_array_equal(vals[:2], [20, 40])
