"""Fast geometric levels (paper 2.2.1) — distribution + oracle agreement."""
import jax
import numpy as np
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core.levels_rng import MAXLEVEL, fast_geometric_levels
from repro.core.skiplist_ref import SkipListRef, ffs_level


def test_geometric_distribution():
    lv = np.asarray(fast_geometric_levels(jax.random.PRNGKey(0), (100000,)))
    assert lv.min() >= 1 and lv.max() <= MAXLEVEL
    for n, p in ((1, 0.5), (2, 0.25), (3, 0.125), (4, 0.0625)):
        assert abs((lv == n).mean() - p) < 0.01, n


def test_matches_paper_ffs_oracle():
    lv = np.asarray(fast_geometric_levels(jax.random.PRNGKey(1), (100000,)))
    r = np.random.default_rng(0)
    ref = np.array([ffs_level(r) for _ in range(100000)])
    assert abs(lv.mean() - ref.mean()) < 0.02
    assert abs(lv.std() - ref.std()) < 0.05


@settings(max_examples=15, deadline=None,
          suppress_health_check=list(HealthCheck))
@given(seed=st.integers(0, 10**6),
       items=st.lists(st.tuples(st.integers(0, 500), st.integers(0, 99)),
                      min_size=1, max_size=120))
def test_skiplist_ref_is_an_ordered_map(seed, items):
    sl = SkipListRef(seed=seed)
    d = {}
    for k, v in items:
        sl.insert(k, v)
        d[k] = v
    assert sl.items() == sorted(d.items())
    for k, v in d.items():
        assert sl.lookup(k) == v
    assert sl.lookup(10**7) is None
