"""Fast geometric levels (paper 2.2.1) — distribution + oracle agreement.
The hypothesis ordered-map property lives in test_levels_rng_props.py."""
import jax
import numpy as np

from repro.core.levels_rng import MAXLEVEL, fast_geometric_levels
from repro.core.skiplist_ref import ffs_level


def test_geometric_distribution():
    lv = np.asarray(fast_geometric_levels(jax.random.PRNGKey(0), (100000,)))
    assert lv.min() >= 1 and lv.max() <= MAXLEVEL
    for n, p in ((1, 0.5), (2, 0.25), (3, 0.125), (4, 0.0625)):
        assert abs((lv == n).mean() - p) < 0.01, n


def test_matches_paper_ffs_oracle():
    lv = np.asarray(fast_geometric_levels(jax.random.PRNGKey(1), (100000,)))
    r = np.random.default_rng(0)
    ref = np.array([ffs_level(r) for _ in range(100000)])
    assert abs(lv.mean() - ref.mean()) < 0.02
    assert abs(lv.std() - ref.std()) < 0.05
