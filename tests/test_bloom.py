"""Bloom filter invariants (paper 2.3). Hypothesis property tests live
in test_bloom_props.py (skipped gracefully when hypothesis is absent)."""
import jax.numpy as jnp
import numpy as np

from repro.core.bloom import bloom_build, bloom_insert, bloom_probe
from repro.core.params import SLSMParams


def test_fp_rate_tracks_eps(rng):
    p = SLSMParams(eps=0.01)
    n = 4000
    bits, words, k = p.bloom_geometry(n)
    present = rng.choice(2**24, size=n, replace=False).astype(np.int32)
    filt = bloom_build(jnp.asarray(present), jnp.ones(n, bool), words, k)
    absent = (rng.choice(2**24, size=20000, replace=False)
              .astype(np.int64) + 2**24).astype(np.int32)
    fp = np.asarray(bloom_probe(filt, jnp.asarray(absent), k)).mean()
    assert fp < 5 * p.eps, fp  # within a small factor of the target


def test_insert_is_incremental_or(rng):
    a = rng.integers(0, 2**30, 100).astype(np.int32)
    b = rng.integers(0, 2**30, 100).astype(np.int32)
    both = bloom_build(jnp.asarray(np.concatenate([a, b])),
                       jnp.ones(200, bool), 64, 5)
    stepwise = bloom_build(jnp.asarray(a), jnp.ones(100, bool), 64, 5)
    stepwise = bloom_insert(stepwise, jnp.asarray(b), jnp.ones(100, bool), 5)
    np.testing.assert_array_equal(np.asarray(both), np.asarray(stepwise))


def test_invalid_keys_not_inserted():
    ks = jnp.asarray(np.asarray([5, 6, 7], np.int32))
    valid = jnp.asarray([True, False, True])
    filt = bloom_build(ks, valid, 64, 5)
    probe = np.asarray(bloom_probe(filt, ks, 5))
    assert probe[0] and probe[2]
    # key 6 was masked out; it may still collide, but with 64*32 bits and
    # 2 inserted keys the probability is negligible
    assert not probe[1]
